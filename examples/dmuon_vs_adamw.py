"""Figure 1(c) analogue: wall-clock convergence of DMuon vs AdamW.

    PYTHONPATH=src python examples/dmuon_vs_adamw.py --steps 120

Trains the same ~5M model with both optimizers on the same synthetic stream
and prints aligned loss curves — Muon's per-step convergence advantage with
DMuon's near-AdamW step cost is the paper's wall-clock argument.
"""

import argparse
import time

import jax

from repro import configs
from repro.core import api
from repro.core.muon import MuonConfig
from repro.data.pipeline import DataConfig, batch_for_step
from repro.models import model_fns
from repro.train.step import init_state, make_loss_fn, make_train_step


def train(cfg, mode, steps, lr):
    shapes = jax.eval_shape(lambda k: model_fns(cfg).init(cfg, k),
                            jax.random.PRNGKey(0))
    plan = api.dedicate_params(shapes, strategy="greedy")
    opt = api.Muon(plan, config=MuonConfig(mode=mode, learning_rate=lr,
                                           adam_lr=3e-3))
    state = init_state(cfg, opt, jax.random.PRNGKey(0))
    step = make_train_step(cfg, opt, donate=False)
    loss_fn = jax.jit(make_loss_fn(cfg))
    dcfg = DataConfig(vocab=cfg.vocab, seq_len=128, global_batch=8)
    curve, times = [], []
    t0 = time.time()
    for i in range(steps):
        batch = batch_for_step(dcfg, i)
        if i % 10 == 0:
            curve.append(float(loss_fn(state.params, batch)))
            times.append(time.time() - t0)
        state = step(state, batch)
    return curve, times


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=120)
    args = ap.parse_args()
    cfg = configs.get("smollm-360m", n_layers=4, d_model=256, n_heads=4,
                      n_kv_heads=2, d_ff=704, vocab=4096, head_dim=64,
                      remat=False)
    dm_curve, dm_t = train(cfg, "owner", args.steps, lr=0.02)
    ad_curve, ad_t = train(cfg, "adamw", args.steps, lr=0.02)
    print(f"{'step':>5} | {'DMuon loss':>10} | {'AdamW loss':>10}")
    for i, (a, b) in enumerate(zip(dm_curve, ad_curve)):
        print(f"{i*10:5d} | {a:10.4f} | {b:10.4f}")
    print(f"\nwall: DMuon {dm_t[-1]:.1f}s vs AdamW {ad_t[-1]:.1f}s "
          f"for {args.steps} steps")
    better = sum(1 for a, b in zip(dm_curve[2:], ad_curve[2:]) if a < b)
    print(f"DMuon ahead at {better}/{len(dm_curve)-2} checkpoints")


if __name__ == "__main__":
    main()
