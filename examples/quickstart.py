"""Quickstart: the three-line DMuon API (paper Fig. 1a) on a tiny LM.

    PYTHONPATH=src python examples/quickstart.py [--variant muon|normuon|muonbp|adamw]

Builds a reduced smollm config, dedicates parameters, trains 20 steps with
owner-centric DMuon (or a registered optimizer variant) and prints the loss
curve.
"""

import argparse

import jax

from repro import configs
from repro.core import api                              # the drop-in module
from repro.core.muon import MuonConfig
from repro.data.pipeline import DataConfig, batch_for_step
from repro.models import model_fns
from repro.train.step import init_state, make_train_step


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--variant", default="muon",
                    choices=sorted(api.VARIANTS),
                    help="optimizer variant (see the registry in core/api.py)")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--pipeline", default="fused",
                    choices=["fused", "bucketed"],
                    help="optimizer-step schedule (docs/DESIGN.md §6)")
    args = ap.parse_args()

    cfg = configs.get("smollm-360m", reduced=True)
    shapes = jax.eval_shape(lambda k: model_fns(cfg).init(cfg, k),
                            jax.random.PRNGKey(0))

    # --- the paper's three lines -----------------------------------------
    plan = api.dedicate_params(shapes)                  # 1. dedicate
    opt = api.Muon(plan, config=MuonConfig(             # 2. construct
        learning_rate=0.02, momentum=0.95, variant=args.variant,
        pipeline=args.pipeline))
    state = init_state(cfg, opt, jax.random.PRNGKey(0))  # 3. init / update
    # ----------------------------------------------------------------------

    print(f"variant: {args.variant} — {opt.variant.description}")
    print(f"matrices under Muon: {plan.stats['num_matrices']} in "
          f"{plan.stats['num_groups']} groups; "
          f"{plan.stats['num_adamw_leaves']} AdamW leaves")

    step = make_train_step(cfg, opt, donate=False)
    dcfg = DataConfig(vocab=cfg.vocab, seq_len=64, global_batch=8)
    for i in range(args.steps):
        state = step(state, batch_for_step(dcfg, i))
        if i % 5 == 4:
            print(f"step {int(state.step):3d}  loss_ema "
                  f"{float(state.loss_ema):.4f}")
    print("done.")


if __name__ == "__main__":
    main()
