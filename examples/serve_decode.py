"""Serving example (deliverable b): batched prefill + decode loop.

    PYTHONPATH=src python examples/serve_decode.py --arch qwen2.5-14b
    PYTHONPATH=src python examples/serve_decode.py --arch xlstm-350m

Runs the reduced config of the chosen architecture: prefill a batch of
prompts, then decode N tokens with the KV-cache / recurrent-state machinery,
reporting per-token latency.
"""

import argparse
import time

import jax
import jax.numpy as jnp

from repro import configs
from repro.models import model_fns


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-14b",
                    choices=list(configs.ARCH_IDS))
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=32)
    args = ap.parse_args()

    cfg = configs.get(args.arch, reduced=True)
    m = model_fns(cfg)
    params = jax.jit(lambda k: m.init(cfg, k))(jax.random.PRNGKey(0))
    B, S = args.batch, args.prompt_len
    max_len = S + args.new_tokens + 8

    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    tokens = jax.random.randint(ks[0], (B, S), 0, cfg.vocab)
    extra = {}
    prefix = 0
    if cfg.encdec:
        extra["frames"] = jax.random.normal(
            ks[1], (B, S, cfg.frontend_dim)) * 0.1
    elif cfg.frontend == "patch":
        extra["patches"] = jax.random.normal(
            ks[1], (B, cfg.frontend_len, cfg.frontend_dim)) * 0.1
        prefix = cfg.frontend_len

    t0 = time.perf_counter()
    if cfg.encdec:
        logits, cache = m.prefill(cfg, params, tokens,
                                  frames=extra["frames"], max_len=max_len)
    elif cfg.family == "ssm":
        logits, cache = m.prefill(cfg, params, tokens, max_len)
    else:
        logits, cache = m.prefill(cfg, params, tokens, max_len + prefix,
                                  **extra)
    jax.block_until_ready(logits)
    print(f"prefill: batch={B} prompt={S} "
          f"({time.perf_counter()-t0:.2f}s incl. compile)")

    decode = jax.jit(lambda p, t, c, pos: m.decode_step(cfg, p, t, c, pos))
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    seqs = [tok]
    t0 = time.perf_counter()
    for i in range(args.new_tokens):
        logits, cache = decode(params, tok, cache,
                               jnp.asarray(S + prefix + i, jnp.int32))
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        seqs.append(tok)
    jax.block_until_ready(tok)
    dt = time.perf_counter() - t0
    out = jnp.stack(seqs, 1)
    print(f"decoded {args.new_tokens} tokens x {B} seqs in {dt:.2f}s "
          f"({dt/args.new_tokens*1e3:.1f} ms/token incl. first-step compile)")
    print("sample token ids:", out[0, :16].tolist())


if __name__ == "__main__":
    main()
