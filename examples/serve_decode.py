"""Serving example (deliverable b): one-shot batch or continuous batching.

    PYTHONPATH=src python examples/serve_decode.py --arch qwen2.5-14b
    PYTHONPATH=src python examples/serve_decode.py --arch xlstm-350m \
        --mode continuous --requests 12 --rate 50

``--mode oneshot`` (default) is the original static-batch loop: prefill a
batch of prompts together, decode in lockstep, report per-token latency.
``--mode continuous`` drives the same reduced model through the serving
tier (repro.serve): a synthetic request workload flows through the slot
scheduler — insert on free, evict on budget, recycle cache rows — and the
summary reports TTFT / throughput / slot occupancy.
"""

import argparse

import jax

from repro import configs
from repro.models import model_fns
from repro.serve import RequestQueue, Scheduler, ServeConfig, run_oneshot


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-14b",
                    choices=list(configs.ARCH_IDS))
    ap.add_argument("--mode", default="oneshot",
                    choices=["oneshot", "continuous"])
    ap.add_argument("--batch", type=int, default=4,
                    help="static batch (oneshot) / decode slots (continuous)")
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--requests", type=int, default=12,
                    help="continuous: synthetic workload size")
    ap.add_argument("--rate", type=float, default=None,
                    help="continuous: arrivals/sec (default: all at t=0)")
    ap.add_argument("--kv", default="contiguous",
                    choices=["contiguous", "paged"],
                    help="continuous: cache layout — contiguous per-slot "
                         "rows, or the paged block pool (DESIGN.md §12)")
    ap.add_argument("--block-size", type=int, default=16,
                    help="paged: tokens per cache block")
    ap.add_argument("--pool-blocks", type=int, default=None,
                    help="paged: pool size in blocks (default: same bytes "
                         "as the contiguous reservation)")
    args = ap.parse_args()

    cfg = configs.get(args.arch, reduced=True)
    m = model_fns(cfg)
    params = jax.jit(lambda k: m.init(cfg, k))(jax.random.PRNGKey(0))
    S = args.prompt_len
    max_len = S + args.new_tokens + 8
    enc_kw = dict(frontend_dim=cfg.frontend_dim) \
        if (cfg.encdec or cfg.frontend is not None) else {}
    if cfg.frontend == "patch":
        # patch prompts carry a fixed image prefix, not per-token frames;
        # the synthetic workload generates frames at frontend geometry
        enc_kw = {}

    if args.mode == "oneshot":
        queue = RequestQueue.synthetic(
            args.batch, cfg.vocab, prompt_lens=(S,),
            new_tokens=(args.new_tokens + 1, args.new_tokens + 1),
            seed=1, **enc_kw)
        queue.poll(0.0)
        reqs = [queue.pop_group(1)[0] for _ in range(len(queue))]
        if cfg.frontend == "patch":
            import numpy as np
            rng = np.random.default_rng(1)
            for r in reqs:
                r.frames = (rng.standard_normal(
                    (cfg.frontend_len, cfg.frontend_dim)) * 0.1
                ).astype(np.float32)
        metrics = run_oneshot(cfg, params, reqs, batch=args.batch,
                              max_len=max_len)
        s = metrics.summary()
        print(f"oneshot: batch={args.batch} prompt={S} "
              f"new={args.new_tokens}")
        print(f"decoded {s['tokens']} tokens in {s['wall_s']:.2f}s "
              f"({s['per_token_ms_median']:.1f} ms/token median, "
              f"incl. compile)")
        rec = next(iter(metrics.requests.values()))
        print("sample token ids:", rec.tokens[:16])
        return

    queue = RequestQueue.synthetic(
        args.requests, cfg.vocab, prompt_lens=(S,),
        new_tokens=(2, args.new_tokens), rate=args.rate, seed=1, **enc_kw)
    scfg = ServeConfig(num_slots=args.batch, max_len=max_len,
                       enc_len=S if cfg.encdec else None,
                       kv=args.kv, block_size=args.block_size,
                       pool_blocks=args.pool_blocks)
    if cfg.frontend == "patch":
        raise SystemExit("continuous mode: patch-frontend archs need "
                         "per-request images; use --mode oneshot")
    if cfg.encdec and args.kv == "paged":
        raise SystemExit("paged KV covers decoder-only archs; enc-dec "
                         "serves with --kv contiguous")
    sched = Scheduler(cfg, params, scfg)
    metrics = sched.run(queue)
    s = metrics.summary()
    print(f"continuous[{args.kv}]: slots={args.batch} "
          f"requests={s['requests']} (rate={args.rate or 'all-at-once'})")
    print(f"  tokens            {s['tokens']}  in {s['wall_s']:.2f}s "
          f"(incl. compile)")
    print(f"  tokens/sec        {s['tokens_per_sec']:.1f}")
    print(f"  ttft ms           {s['ttft_ms_median']:.1f} median / "
          f"{s['ttft_ms_p90']:.1f} p90")
    print(f"  per-token ms      {s['per_token_ms_median']:.1f} median")
    print(f"  decode steps      {s['decode_steps']}  "
          f"(occupancy {s['slot_occupancy']:.2f})")
    if args.kv == "paged":
        print(f"  pool blocks       {s.get('pool_blocks', 0)}  "
              f"(occupancy {s.get('pool_occupancy', 0.0):.2f}, "
              f"frag {s.get('frag_pct', 0.0):.1f}%)")
        print(f"  preemptions       {s['preemptions']}  "
              f"rejected {s['rejected']}")
    rec = next(iter(metrics.requests.values()))
    print("sample token ids:", rec.tokens[:16])


if __name__ == "__main__":
    main()
