"""End-to-end training driver (deliverable b): train a ~100M-class smollm
variant for a few hundred steps with DMuon, with checkpointing + restart.

    PYTHONPATH=src python examples/train_smollm.py --steps 200
    PYTHONPATH=src python examples/train_smollm.py --steps 200 --opt adamw
    PYTHONPATH=src python examples/train_smollm.py --resume   # from last ckpt

On this CPU container the default is a ~20M-param scaled config (wall-clock
budget); pass --full-360m to train the real smollm-360m architecture.
"""

import argparse
import time

import jax

from repro import configs
from repro.checkpoint.manager import CheckpointManager
from repro.core import api
from repro.core.muon import MuonConfig
from repro.data.pipeline import DataConfig, Pipeline, batch_for_step
from repro.models import model_fns
from repro.train.step import init_state, make_train_step
from repro.train.train_state import TrainState


def build(args):
    if args.full_360m:
        cfg = configs.get("smollm-360m")
    else:  # ~20M params: same family, CPU-budget width
        cfg = configs.get("smollm-360m", n_layers=8, d_model=384,
                          n_heads=6, n_kv_heads=2, d_ff=1024, vocab=8192,
                          head_dim=64, remat=False)
    shapes = jax.eval_shape(lambda k: model_fns(cfg).init(cfg, k),
                            jax.random.PRNGKey(0))
    plan = api.dedicate_params(shapes, strategy="greedy")
    opt = api.Muon(plan, config=MuonConfig(
        mode=args.opt if args.opt != "muon_ag" else "gather",
        learning_rate=args.lr, adam_lr=3e-3))
    return cfg, plan, opt


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--opt", default="owner",
                    choices=["owner", "muon_ag", "gather", "adamw"])
    ap.add_argument("--lr", type=float, default=0.02)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/dmuon_smollm_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--full-360m", action="store_true")
    args = ap.parse_args()

    cfg, plan, opt = build(args)
    n_params = cfg.param_count()
    print(f"arch={cfg.name} params={n_params/1e6:.1f}M opt={args.opt} "
          f"muon_matrices={plan.stats['num_matrices']}")

    state = init_state(cfg, opt, jax.random.PRNGKey(0))
    mgr = CheckpointManager(args.ckpt_dir, keep=2)
    start = 0
    if args.resume and mgr.latest_step() is not None:
        restored = mgr.restore(like=state._asdict())
        state = TrainState(**restored)
        start = int(state.step)
        print(f"resumed from step {start}")

    step = make_train_step(cfg, opt, donate=False)
    dcfg = DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                      global_batch=args.batch)
    pipe = Pipeline(dcfg, start_step=start, prefetch=2)

    t0 = time.time()
    try:
        for i in range(start, args.steps):
            state = step(state, next(pipe))
            if (i + 1) % 10 == 0:
                rate = (i + 1 - start) / (time.time() - t0)
                print(f"step {i+1:4d}  loss_ema {float(state.loss_ema):.4f} "
                      f"  {rate:.2f} steps/s", flush=True)
            if (i + 1) % args.ckpt_every == 0:
                mgr.save(i + 1, state._asdict())
    finally:
        pipe.close()
        mgr.wait()
    print(f"final loss_ema {float(state.loss_ema):.4f}")


if __name__ == "__main__":
    main()
