"""Resilient-loop drills (ISSUE: survivable training loop).

Quick tests: FaultPlan DSL round-trip + validation, the supervised loop's
bit-equivalence to a manual train loop, and rebalance hysteresis (a
persistent straggler triggers exactly one re-plan).

Slow soak (marked ``slow``): a 60-step run per optimizer variant through the
full drill — slow + recover, owner kill + re-add, preemption + checkpoint
restore — asserting the *logical* optimizer trajectory (params, loss curve,
unpacked momentum/variant-state rows) is bit-identical to an unfaulted run
at equal step counts.
"""

import jax
import numpy as np
import pytest

from repro import configs
from repro.core import api
from repro.core.muon import MuonConfig, group_key_str
from repro.data.pipeline import DataConfig, batch_for_step
from repro.models import model_fns
from repro.runtime.faults import FaultEvent, FaultInjector, FaultPlan
from repro.runtime.resilient import ResilientConfig, ResilientLoop
from repro.train.step import init_state, make_train_step

VARIANTS = ["muon", "muonbp", "normuon"]


def _model_cfg():
    return configs.get("smollm-360m", reduced=True, n_layers=2)


def _data_cfg(cfg):
    return DataConfig(vocab=cfg.vocab, seq_len=16, global_batch=4)


def _loop(variant="muon", *, steps, num_owners=4, ckpt_dir=None,
          ckpt_every=0, faults=None, **run_kw):
    cfg = _model_cfg()
    run = ResilientConfig(steps=steps, ckpt_every=ckpt_every, **run_kw)
    return ResilientLoop(cfg, _data_cfg(cfg), muon=MuonConfig(variant=variant),
                         run=run, num_owners=num_owners, ckpt_dir=ckpt_dir,
                         faults=faults)


def _logical_rows(plan, bufs):
    """Owner-major (D*cap, m, n) buffers -> logical (count, m, n) rows.
    Owner-count independent: the basis of the bit-continuity assertions."""
    out = {}
    for key, g in plan.groups.items():
        buf = np.asarray(bufs[group_key_str(key)])
        out[group_key_str(key)] = buf[np.asarray(g.unpack_index)]
    return out


def _assert_same_trajectory(a, b):
    """a, b: finished ResilientLoops at equal logical step counts."""
    jax.tree_util.tree_map(
        lambda x, y: np.testing.assert_array_equal(np.asarray(x),
                                                   np.asarray(y)),
        a.state.params, b.state.params)
    assert a.report.loss_curve() == b.report.loss_curve()
    ra = _logical_rows(a.plan, a.state.opt_state.momentum)
    rb = _logical_rows(b.plan, b.state.opt_state.momentum)
    assert ra.keys() == rb.keys()
    for k in ra:
        np.testing.assert_array_equal(ra[k], rb[k], err_msg=f"momentum {k}")
    va, vb = a.state.opt_state.variant_state, b.state.opt_state.variant_state
    assert (va is None) == (vb is None)
    if va is not None:
        assert va.keys() == vb.keys()
        for field in va:
            assert (va[field] is None) == (vb[field] is None)
            if va[field] is None:
                continue
            fa = _logical_rows(a.plan, va[field])
            fb = _logical_rows(b.plan, vb[field])
            for k in fa:
                np.testing.assert_array_equal(
                    fa[k], fb[k], err_msg=f"variant_state {field}/{k}")


# ------------------------------------------------------------ FaultPlan DSL


def test_fault_plan_parse_roundtrip():
    spec = "slow@8:r3x4.0; unslow@24:r3; kill@30:r1; readd@40; preempt@52"
    plan = FaultPlan.parse(spec)
    assert len(plan) == 5
    assert plan.max_step == 52
    assert FaultPlan.parse(plan.spec()).events == plan.events
    assert plan.at(30) == [FaultEvent(step=30, kind="kill", owner=1)]
    assert plan.at(8)[0].factor == 4.0
    assert plan.at(7) == []


@pytest.mark.parametrize("bad", [
    "flood@3",             # unknown kind
    "slow@3",              # slow needs an owner
    "kill@3",              # kill needs an owner
    "slow@3:r1x0.5",       # speedup is not a fault
    "slow@-1:r0x2",        # negative step doesn't parse
    "kill@3 r1",           # malformed clause
])
def test_fault_plan_rejects_bad_specs(bad):
    with pytest.raises(ValueError):
        FaultPlan.parse(bad)


def test_injector_renumber_and_multipliers():
    inj = FaultInjector(FaultPlan.parse("slow@0:r3x4.0; slow@0:r1x2.0"))
    assert inj.events_at(0)                       # fires both slow events
    np.testing.assert_allclose(inj.multipliers(4), [1, 2, 1, 4])
    inj.on_owner_renumber(2)                      # slot 3 shifts down to 2
    np.testing.assert_allclose(inj.multipliers(3), [1, 2, 4])
    assert inj.events_at(0) == []                 # exactly-once


# ------------------------------------------------- loop ≡ manual (unfaulted)


def test_loop_matches_manual_train_loop():
    """The supervisor adds zero numerics: a supervised run is bit-identical
    to hand-stepping make_train_step over batch_for_step."""
    steps = 5
    loop = _loop(steps=steps, num_owners=2)
    report = loop.run()
    assert report.steps == steps
    assert report.executed_steps == steps

    cfg = _model_cfg()
    shapes = jax.eval_shape(lambda k: model_fns(cfg).init(cfg, k),
                            jax.random.PRNGKey(0))
    plan = api.dedicate_params(shapes, num_owners=2, strategy="greedy")
    opt = api.Muon(plan, config=MuonConfig())
    state = init_state(cfg, opt, jax.random.PRNGKey(0))
    step_fn = make_train_step(cfg, opt, donate=False)
    dcfg = _data_cfg(cfg)
    for i in range(steps):
        state = step_fn(state, batch_for_step(dcfg, i))
    jax.tree_util.tree_map(
        lambda x, y: np.testing.assert_array_equal(np.asarray(x),
                                                   np.asarray(y)),
        loop.state.params, state.params)
    assert float(loop.state.loss_ema) == float(state.loss_ema)


# --------------------------------------------------------------- hysteresis


def test_rebalance_fires_once_for_persistent_straggler():
    """A 4x-slow owner trips the monitor once; after the re-plan the baked-in
    speeds match the estimate, so hysteresis suppresses further re-fires."""
    loop = _loop(steps=16, num_owners=4,
                 faults=FaultPlan.parse("slow@1:r3x4.0"),
                 window=4, cooldown=3, threshold=1.3)
    report = loop.run()
    assert len(report.rebalances) == 1
    rb = report.rebalances[0]
    assert rb["speed"][3] < 0.5                  # measured ~1/4 speed
    assert rb["makespan_after_s"] < rb["makespan_before_s"]
    assert report.steps == 16


def test_rebalance_preserves_trajectory():
    """The re-plan is scheduling metadata only: the rebalanced run stays
    bit-identical to an unfaulted one."""
    faulted = _loop(steps=12, num_owners=4,
                    faults=FaultPlan.parse("slow@1:r3x4.0"),
                    window=4, cooldown=3, threshold=1.3)
    faulted.run()
    assert faulted.report.rebalances
    plain = _loop(steps=12, num_owners=4)
    plain.run()
    _assert_same_trajectory(faulted, plain)


# ---------------------------------------- kill/readd drill (new variants)


@pytest.mark.parametrize("variant", ["dion2", "adamuon"])
def test_kill_readd_drill_new_variants(variant):
    """Quick elasticity drill for the shrunken-factor / second-moment
    variants: an owner kill + re-add mid-run must leave the logical
    trajectory bit-identical to an unfaulted run — their owner-major
    q/v buffers ride reshard_owner_state exactly like the momentum."""
    faulted = _loop(variant, steps=14, num_owners=4,
                    faults=FaultPlan.parse("kill@4:r1; readd@9"))
    report = faulted.run()
    assert report.steps == 14
    assert report.final_owner_count == 4
    kinds = [r["kind"] for r in report.recoveries]
    assert kinds.count("kill") == 1 and kinds.count("readd") == 1

    plain = _loop(variant, steps=14, num_owners=4)
    plain.run()
    _assert_same_trajectory(faulted, plain)


# --------------------------------------------------------------------- soak


@pytest.mark.slow
@pytest.mark.parametrize("variant", VARIANTS)
def test_soak_full_drill_bit_continuity(tmp_path, variant):
    """60-step survivability drill per variant: slow+recover, kill+readd,
    preempt+restore — logical trajectory bit-identical to an unfaulted run."""
    drill = "slow@8:r3x4.0; unslow@24:r3; kill@30:r1; readd@40; preempt@52"
    faulted = _loop(variant, steps=60, num_owners=4,
                    ckpt_dir=str(tmp_path / "ckpt"), ckpt_every=16,
                    faults=FaultPlan.parse(drill),
                    window=8, cooldown=10, threshold=1.3)
    report = faulted.run()

    assert report.steps == 60
    assert report.final_owner_count == 4          # kill@30 then readd@40
    # preempt@52 rewinds to the step-48 checkpoint: 4 replayed steps
    assert report.executed_steps == 64
    kinds = [r["kind"] for r in report.recoveries]
    assert kinds.count("kill") == 1
    assert kinds.count("readd") == 1
    assert kinds.count("preempt") == 1
    preempt = next(r for r in report.recoveries if r["kind"] == "preempt")
    assert preempt["resumed_step"] == 48
    assert report.rebalances, "slow@8 must trigger a re-plan"
    rb = report.rebalances[0]
    assert rb["makespan_after_s"] < rb["makespan_before_s"]
    assert report.checkpoints and max(report.checkpoints) >= 48

    plain = _loop(variant, steps=60, num_owners=4)
    plain_report = plain.run()
    assert plain_report.steps == 60
    _assert_same_trajectory(faulted, plain)
