"""Multi-device owner-centric execution, via a subprocess with 8 host devices.

The main test process must keep seeing a single device (per the dry-run
isolation rule), so the 8-device parity checks run in a child process with
XLA_FLAGS=--xla_force_host_platform_device_count=8.
"""

import os
import subprocess
import sys

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.mark.slow
def test_distributed_parity_8_devices():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    proc = subprocess.run(
        [sys.executable, os.path.join(ROOT, "tests", "dist_check.py")],
        env=env, capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, \
        f"STDOUT:\n{proc.stdout}\nSTDERR:\n{proc.stderr}"
    assert "ALL DISTRIBUTED CHECKS PASSED" in proc.stdout
