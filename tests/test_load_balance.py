"""Owner assignment: MILP (Eq. 5), greedy fallback, ablation strategies, XOR layout."""

import numpy as np
import pytest

from repro.core import load_balance as lb
from repro.core.layout import (node_of_slot, column_of_slot, owner_slot,
                               slot_sequence)

SHAPES = {(1024, 4096): 32, (1024, 1024): 64, (128, 512): 96, (4096, 4096): 8}


@pytest.fixture(scope="module")
def cm():
    return lb.analytic_cost_model(SHAPES)


def _check_coverage(asn, shapes):
    """Eq. 5 equality constraint: every matrix assigned exactly once."""
    for s, n in shapes.items():
        assert len(asn.owner_of[s]) == n
        assert sum(b for b, _ in asn.chunks[s]) == n


@pytest.mark.parametrize("solver", ["milp", "greedy", "lpt"])
def test_solvers_cover_all_matrices(cm, solver):
    fn = {"milp": lb.solve_milp, "greedy": lb.solve_greedy,
          "lpt": lb.solve_lpt}[solver]
    asn = fn(SHAPES, cm, 8)
    _check_coverage(asn, SHAPES)
    assert asn.makespan(cm) > 0


def test_milp_beats_naive_strategies(cm):
    milp = lb.solve_milp(SHAPES, cm, 8)
    rr = lb.round_robin(SHAPES, 8)
    r0 = lb.rank0(SHAPES, 8)
    assert milp.makespan(cm) <= rr.makespan(cm) + 1e-12
    # rank0 concentrates everything on one owner — the ablation worst case
    assert r0.makespan(cm) >= milp.makespan(cm) * 4
    # MILP is within a small factor of the trivial lower bound (total/owners)
    total = sum(cm.per_matrix(s) * n for s, n in SHAPES.items())
    assert milp.makespan(cm) <= 2.0 * max(total / 8,
                                          max(cm.cost(s, 1) for s in SHAPES))


def test_greedy_close_to_milp(cm):
    milp = lb.solve_milp(SHAPES, cm, 4)
    greedy = lb.solve_greedy(SHAPES, cm, 4)
    assert greedy.makespan(cm) <= 1.5 * milp.makespan(cm) + 1e-9


def test_s_thr_fallback(cm):
    # tiny threshold forces greedy even through the MILP front door
    asn = lb.solve_milp(SHAPES, cm, 64, s_thr=10)
    assert asn.strategy == "greedy"
    _check_coverage(asn, SHAPES)


def test_speed_aware_rebalancing(cm):
    """Straggler mitigation: a 4x slower owner must receive less work."""
    speed = np.ones(8)
    speed[3] = 0.25
    asn = lb.solve_greedy(SHAPES, cm, 8, speed=speed)
    loads = asn.loads(cm)                   # raw work (not speed-scaled)
    assert loads[3] < np.mean(np.delete(loads, 3))
    base = lb.solve_greedy(SHAPES, cm, 8)
    assert asn.makespan(cm, speed) <= base.makespan(cm, speed)


def test_rank0_and_round_robin_shapes(cm):
    for strat in ("round_robin", "rank0", "xor"):
        asn = lb.assign(SHAPES, 8, strategy=strat, rows=2, cols=4)
        _check_coverage(asn, SHAPES)
    r0 = lb.assign(SHAPES, 8, strategy="rank0")
    assert all((v == 0).all() for v in r0.owner_of.values())


def test_cost_model_batching_amortizes_small_shapes():
    """Fig. 7: small matrices gain from batching, big ones saturate alone."""
    shapes = {(256, 256): 16, (4096, 16384): 4}
    cm = lb.analytic_cost_model(shapes, batch_sizes=(1, 16))
    small_gain = cm.cost((256, 256), 1) / (cm.cost((256, 256), 16) / 16)
    big_gain = cm.cost((4096, 16384), 1) / (cm.cost((4096, 16384), 16) / 16)
    assert small_gain > big_gain
    assert small_gain > 1.2


# ---------------------------- XOR layout (Eq. 3) ---------------------------

def test_xor_layout_matches_paper_4x8():
    """Figure 4: gpu(w) = w mod 8, node(w) = (w mod 4) xor (w//8 mod 4)."""
    for w in range(64):
        s = owner_slot(w, 4, 8)
        assert column_of_slot(s, 8) == w % 8
        assert node_of_slot(s, 8) == ((w % 4) ^ ((w // 8) % 4))


def test_xor_layout_balance_and_dispersal():
    rows, cols = 4, 8
    seq = slot_sequence(rows * cols * 3, rows, cols)
    # balance: every slot owns the same number of matrices
    counts = np.bincount(seq, minlength=rows * cols)
    assert counts.min() == counts.max() == 3
    # dispersal: consecutive matrices land on distinct columns
    colseq = seq % cols
    assert all(colseq[i] != colseq[i + 1] for i in range(len(seq) - 1))
    # rotation: consecutive groups of `cols` use different nodes per column
    for g in range(3):
        nodes_g = set(seq[g * cols:(g + 1) * cols] // cols)
        assert len(nodes_g) >= 1


def test_xor_layout_non_pow2_fallback_balanced():
    rows, cols = 3, 6   # additive rotation path
    seq = slot_sequence(rows * cols * 2, rows, cols)
    counts = np.bincount(seq, minlength=rows * cols)
    assert counts.min() == counts.max() == 2
