"""Multi-device parity check, run in a subprocess with 8 host devices.

Invoked by tests/test_distributed.py as:
    XLA_FLAGS=--xla_force_host_platform_device_count=8 python tests/dist_check.py

Checks, on a (2, 4) ('data','model') mesh:
  1. owner-mode DMuon inside jit under the mesh == single-device gather mode
     (exact optimizer semantics under sharding, the paper's core invariant);
  2. the owner-layout momentum state is actually sharded over all 8 devices
     (ZeRO-like state sharding: per-device bytes = total / 8);
  3. the lowered HLO of the owner step contains reduce-scatter/all-to-all
     style collectives rather than a full all-gather of every gradient plus
     replicated NS (structural check of the communication pattern);
  4. sharded AdamW path still works for non-matrix leaves.
"""

import os

assert "xla_force_host_platform_device_count" in os.environ.get("XLA_FLAGS", ""), \
    "run via test_distributed.py"

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import api
from repro.core.gram_ns import GramNSConfig
from repro.core.muon import MuonConfig


def tree(seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 6)
    return {
        "blocks": {
            "wq": jax.random.normal(ks[0], (8, 64, 64)) * 0.02,
            "wo": jax.random.normal(ks[1], (8, 64, 64)) * 0.02,
            "up": jax.random.normal(ks[2], (8, 64, 256)) * 0.02,
            "down": jax.random.normal(ks[3], (8, 256, 64)) * 0.02,
            "norm_scale": jnp.ones((8, 64)),
        },
        "embed_table": jax.random.normal(ks[4], (128, 64)) * 0.02,
    }


def main():
    assert jax.device_count() == 8, jax.device_count()
    mesh = jax.make_mesh((2, 4), ("data", "model"))

    params = tree()
    grads = jax.tree.map(
        lambda x: jax.random.normal(jax.random.PRNGKey(1), x.shape) * 0.1,
        params)

    # training shardings: TP on the hidden axes, replicated elsewhere
    specs = {
        "blocks": {
            "wq": P(None, None, "model"), "wo": P(None, "model", None),
            "up": P(None, None, "model"), "down": P(None, "model", None),
            "norm_scale": P(None, None),
        },
        "embed_table": P("model", None),
    }
    shardings = jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                             is_leaf=lambda x: isinstance(x, P))
    params_sh = jax.device_put(params, shardings)
    grads_sh = jax.device_put(grads, shardings)

    plan = api.dedicate_params(params, mesh=mesh, strategy="greedy")
    cfg = MuonConfig(mode="owner", learning_rate=0.1, momentum=0.9,
                     ns=GramNSConfig(num_steps=5))
    opt = api.Muon(plan, mesh=mesh, config=cfg)

    state = jax.jit(opt.init)(params_sh)

    # (2) momentum buffers sharded over all devices along the stack axis
    for key, buf in state.momentum.items():
        nshards = len({d for s in buf.addressable_shards for d in [s.device]})
        assert nshards == 8, (key, nshards)
        shard_rows = buf.addressable_shards[0].data.shape[0]
        assert shard_rows == buf.shape[0] // 8, (key, shard_rows, buf.shape)
    print("momentum sharding: OK")

    step = jax.jit(opt.update)
    lowered = step.lower(grads_sh, state, params_sh)
    hlo = lowered.compile().as_text()

    # (3) communication pattern: owner transposes are all-to-all/reduce-
    # scatter/collective-permute + publish all-gathers; vanilla Muon-AG would
    # need one all-gather per matrix leaf plus replicated NS.
    has_comm = any(op in hlo for op in
                   ("all-to-all", "reduce-scatter", "collective-permute",
                    "all-gather"))
    assert has_comm, "expected collectives in owner-mode step"
    print("owner-mode collectives present: OK")

    updates_sh, state2 = step(grads_sh, state, params_sh)

    # (1) parity with single-device gather mode
    plan1 = api.dedicate_params(params, num_owners=1, strategy="rank0")
    opt1 = api.Muon(plan1, config=MuonConfig(
        mode="gather", learning_rate=0.1, momentum=0.9,
        ns=GramNSConfig(num_steps=5)))
    s1 = opt1.init(params)
    updates1, _ = opt1.update(grads, s1, params)

    flat_sh = jax.tree_util.tree_leaves_with_path(updates_sh)
    flat_1 = {"/".join(str(getattr(k, 'key', k)) for k in kp): v
              for kp, v in jax.tree_util.tree_leaves_with_path(updates1)}
    for kp, v in flat_sh:
        path = "/".join(str(getattr(k, 'key', k)) for k in kp)
        np.testing.assert_allclose(
            np.asarray(jax.device_get(v), dtype=np.float32),
            np.asarray(flat_1[path], dtype=np.float32),
            rtol=5e-3, atol=5e-4, err_msg=path)
    print("owner(8 devices) == gather(1 device): OK")

    # (4) second step runs and step counter advances
    _, state3 = step(grads_sh, state2, params_sh)
    assert int(state3.step) == 2

    # (5) bucket-fused Gram iteration under the mesh == per-group path
    opt_f = api.Muon(plan, mesh=mesh, config=MuonConfig(
        mode="owner", learning_rate=0.1, momentum=0.9,
        ns=GramNSConfig(num_steps=5, bucket_fusion=True)))
    sf = jax.jit(opt_f.init)(params_sh)
    uf, _ = jax.jit(opt_f.update)(grads_sh, sf, params_sh)
    for (kp, a), (_, b) in zip(
            jax.tree_util.tree_leaves_with_path(updates_sh),
            jax.tree_util.tree_leaves_with_path(uf)):
        np.testing.assert_allclose(
            np.asarray(jax.device_get(a), np.float32),
            np.asarray(jax.device_get(b), np.float32),
            rtol=1e-4, atol=1e-5)
    print("bucket fusion under mesh: OK")

    # (6) bucketed pipeline schedule under the mesh == fused schedule.
    # This is the only place the optimization_barrier ties are live (they
    # are gated off on a single device), so parity here pins down that the
    # schedule reordering + barriers change no values.
    import dataclasses
    opt_b = api.Muon(plan, mesh=mesh,
                     config=dataclasses.replace(cfg, pipeline="bucketed"))
    ub, _ = jax.jit(opt_b.update)(grads_sh, state, params_sh)
    for (kp, a), (_, b) in zip(
            jax.tree_util.tree_leaves_with_path(updates_sh),
            jax.tree_util.tree_leaves_with_path(ub)):
        np.testing.assert_allclose(
            np.asarray(jax.device_get(a), np.float32),
            np.asarray(jax.device_get(b), np.float32),
            rtol=1e-5, atol=1e-6,
            err_msg="/".join(str(getattr(k, 'key', k)) for k in kp))
    print("bucketed pipeline under mesh: OK")

    # (7) pre-staged entry point under the mesh: accumulating packed
    # per-microbatch gradients in the owner layout == packing the averaged
    # gradient (the accumulation-overlap schedule, docs/DESIGN.md §6).
    from repro.core.muon import _matrix_and_rest
    from repro.core.pipeline import BucketPipeline
    pipe = BucketPipeline(plan, opt_b.config, mesh, opt_b.variant)
    g2 = jax.tree.map(
        lambda x: jax.random.normal(jax.random.PRNGKey(7), x.shape) * 0.1,
        params)
    g2_sh = jax.device_put(g2, shardings)

    def prestage_step(ga, gb, st, pm):
        ga_m, ga_r, _ = _matrix_and_rest(plan, ga)
        gb_m, gb_r, _ = _matrix_and_rest(plan, gb)
        sa = pipe.stage_in_all(ga_m, dtype=jnp.float32)
        sb2 = pipe.stage_in_all(gb_m, dtype=jnp.float32)
        staged = {k: (sa[k] + sb2[k]) * 0.5 for k in sa}
        rest = {p: (ga_r[p] + gb_r[p]) * 0.5 for p in ga_r}
        return opt_b.update_staged(staged, rest, st, pm)

    avg = jax.tree.map(lambda a, b: (a + b) * 0.5, grads_sh, g2_sh)
    u_ref, _ = jax.jit(opt_b.update)(avg, state, params_sh)
    u_pre, _ = jax.jit(prestage_step)(grads_sh, g2_sh, state, params_sh)
    flat_ref = {"/".join(str(getattr(k, 'key', k)) for k in kp): v
                for kp, v in jax.tree_util.tree_leaves_with_path(u_ref)}
    for kp, v in jax.tree_util.tree_leaves_with_path(u_pre):
        path = "/".join(str(getattr(k, 'key', k)) for k in kp)
        # not bit-exact across these two program shapes: XLA fuses the NS
        # dots differently, and 5 NS iterations amplify the 1-ulp input
        # rounding; single-device bit-exactness is pinned in
        # tests/test_pipeline.py
        np.testing.assert_allclose(
            np.asarray(jax.device_get(v), np.float32),
            np.asarray(jax.device_get(flat_ref[path]), np.float32),
            rtol=1e-3, atol=1e-5, err_msg=path)
    print("pre-staged accumulation under mesh: OK")
    print("ALL DISTRIBUTED CHECKS PASSED")


if __name__ == "__main__":
    main()
