"""Elastic restart across owner counts: a checkpoint taken at D owners must
resume bit-exactly at D' owners (node-failure recovery with re-planning)."""

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.core import api
from repro.core.api import reshard_owner_state
from repro.core.muon import MuonConfig
from repro.data.pipeline import DataConfig, batch_for_step
from repro.models import model_fns
from repro.train.step import init_state, make_train_step
from repro.train.train_state import TrainState


def _setup(num_owners):
    cfg = configs.get("smollm-360m", reduced=True)
    shapes = jax.eval_shape(lambda k: model_fns(cfg).init(cfg, k),
                            jax.random.PRNGKey(0))
    plan = api.dedicate_params(shapes, num_owners=num_owners,
                               strategy="greedy")
    opt = api.Muon(plan, config=MuonConfig())
    return cfg, plan, opt


def test_owner_state_reshard_resumes_exactly():
    cfg, plan4, opt4 = _setup(4)
    _, plan2, opt2 = _setup(2)
    dcfg = DataConfig(vocab=cfg.vocab, seq_len=16, global_batch=4)

    # run 3 steps at 4 owners
    state = init_state(cfg, opt4, jax.random.PRNGKey(0))
    step4 = make_train_step(cfg, opt4, donate=False)
    for i in range(3):
        state = step4(state, batch_for_step(dcfg, i))

    # "node failure": re-plan at 2 owners, reshard optimizer state
    opt_state2 = reshard_owner_state(state.opt_state, plan4, plan2)
    state2 = TrainState(state.step, state.params, opt_state2, state.loss_ema)

    # continue 2 steps on each; updates must match exactly step-for-step
    step2 = make_train_step(cfg, opt2, donate=False)
    cont4, cont2 = state, state2
    for i in range(3, 5):
        batch = batch_for_step(dcfg, i)
        cont4 = step4(cont4, batch)
        cont2 = step2(cont2, batch)
    for a, b in zip(jax.tree.leaves(cont4.params),
                    jax.tree.leaves(cont2.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-6)


def test_reshard_momentum_padding_is_zero():
    cfg, plan4, opt4 = _setup(4)
    _, plan8, _ = _setup(8)
    shapes = jax.eval_shape(lambda k: model_fns(cfg).init(cfg, k),
                            jax.random.PRNGKey(0))
    params = model_fns(cfg).init(cfg, jax.random.PRNGKey(0))
    st = opt4.init(params)
    grads = jax.tree.map(
        lambda x: jax.random.normal(jax.random.PRNGKey(9), x.shape) * 0.1,
        params)
    _, st = opt4.update(grads, st, params)
    st8 = reshard_owner_state(st, plan4, plan8)
    for key, g in plan8.groups.items():
        buf = np.asarray(st8.momentum[key.replace("/", ".")],
                         dtype=np.float32)
        assert buf.shape[0] == g.packed_size
        if g.packed_size > g.count:
            assert np.all(buf[g.count:] == 0)        # pads stay zero
