"""Elastic restart across owner counts: a checkpoint taken at D owners must
resume bit-exactly at D' owners (node-failure recovery with re-planning)."""

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.core import api
from repro.core.api import reshard_owner_state
from repro.core.muon import MuonConfig
from repro.data.pipeline import DataConfig, batch_for_step
from repro.models import model_fns
from repro.train.step import init_state, make_train_step
from repro.train.train_state import TrainState


def _setup(num_owners):
    cfg = configs.get("smollm-360m", reduced=True)
    shapes = jax.eval_shape(lambda k: model_fns(cfg).init(cfg, k),
                            jax.random.PRNGKey(0))
    plan = api.dedicate_params(shapes, num_owners=num_owners,
                               strategy="greedy")
    opt = api.Muon(plan, config=MuonConfig())
    return cfg, plan, opt


def test_owner_state_reshard_resumes_exactly():
    cfg, plan4, opt4 = _setup(4)
    _, plan2, opt2 = _setup(2)
    dcfg = DataConfig(vocab=cfg.vocab, seq_len=16, global_batch=4)

    # run 3 steps at 4 owners
    state = init_state(cfg, opt4, jax.random.PRNGKey(0))
    step4 = make_train_step(cfg, opt4, donate=False)
    for i in range(3):
        state = step4(state, batch_for_step(dcfg, i))

    # "node failure": re-plan at 2 owners, reshard optimizer state
    opt_state2 = reshard_owner_state(state.opt_state, plan4, plan2)
    state2 = TrainState(state.step, state.params, opt_state2, state.loss_ema)

    # continue 2 steps on each; updates must match exactly step-for-step
    step2 = make_train_step(cfg, opt2, donate=False)
    cont4, cont2 = state, state2
    for i in range(3, 5):
        batch = batch_for_step(dcfg, i)
        cont4 = step4(cont4, batch)
        cont2 = step2(cont2, batch)
    for a, b in zip(jax.tree.leaves(cont4.params),
                    jax.tree.leaves(cont2.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-6)


def test_reshard_momentum_padding_is_zero():
    cfg, plan4, opt4 = _setup(4)
    _, plan8, _ = _setup(8)
    shapes = jax.eval_shape(lambda k: model_fns(cfg).init(cfg, k),
                            jax.random.PRNGKey(0))
    params = model_fns(cfg).init(cfg, jax.random.PRNGKey(0))
    st = opt4.init(params)
    grads = jax.tree.map(
        lambda x: jax.random.normal(jax.random.PRNGKey(9), x.shape) * 0.1,
        params)
    _, st = opt4.update(grads, st, params)
    st8 = reshard_owner_state(st, plan4, plan8)
    for key, g in plan8.groups.items():
        buf = np.asarray(st8.momentum[key.replace("/", ".")],
                         dtype=np.float32)
        assert buf.shape[0] == g.packed_size
        if g.packed_size > g.count:
            assert np.all(buf[g.count:] == 0)        # pads stay zero


# ----------------------------------------------------------------------
# reshard_owner_state round-trips (D -> D' -> D), incl. non-contiguous
# pack layouts and per-variant owner state
# ----------------------------------------------------------------------

def _logical_rows(plan, key, buf):
    g = plan.groups[key]
    return np.take(np.asarray(buf, dtype=np.float32), g.unpack_index, axis=0)


def _stack_plan(num_owners, physical_layout="contiguous",
                strategy="greedy"):
    # one leaf of 6 stacked matrices: capacity padding at 4 owners, and
    # (round_robin + layout='assignment') a non-contiguous pack_index
    params = {"w": jax.random.normal(jax.random.PRNGKey(0), (6, 8, 24))}
    plan = api.dedicate_params(params, num_owners=num_owners,
                               strategy=strategy,
                               physical_layout=physical_layout)
    return params, plan


def test_reshard_roundtrip_4_2_4_exact():
    """D=4 -> D'=2 -> D=4 must reproduce the original momentum exactly."""
    params, plan4 = _stack_plan(4)
    _, plan2 = _stack_plan(2)
    opt4 = api.Muon(plan4, config=MuonConfig())
    st = opt4.init(params)
    grads = jax.tree.map(
        lambda x: jax.random.normal(jax.random.PRNGKey(3), x.shape) * 0.1,
        params)
    _, st = opt4.update(grads, st, params)

    st2 = reshard_owner_state(st, plan4, plan2)
    back = reshard_owner_state(st2, plan2, plan4)
    for skey, buf in st.momentum.items():
        np.testing.assert_array_equal(np.asarray(buf),
                                      np.asarray(back.momentum[skey]))
        # and the logical rows agree across ALL plans
        np.testing.assert_array_equal(
            _logical_rows(plan4, "w", buf),
            _logical_rows(plan2, "w", st2.momentum[skey]))


def test_reshard_roundtrip_noncontiguous_pack_index():
    """physical_layout='assignment' scatters matrices into owner segments;
    the reshard must follow pack_index, not assume contiguity."""
    params, plan4 = _stack_plan(4, physical_layout="assignment",
                                strategy="round_robin")
    _, plan2 = _stack_plan(2, physical_layout="assignment",
                           strategy="round_robin")
    g4 = plan4.groups["w"]
    assert not np.array_equal(g4.pack_index[:g4.count],
                              np.arange(g4.count)), \
        "test needs a non-contiguous pack layout"

    opt4 = api.Muon(plan4, config=MuonConfig())
    st = opt4.init(params)
    grads = jax.tree.map(
        lambda x: jax.random.normal(jax.random.PRNGKey(4), x.shape) * 0.1,
        params)
    _, st = opt4.update(grads, st, params)

    st2 = reshard_owner_state(st, plan4, plan2)
    back = reshard_owner_state(st2, plan2, plan4)
    for skey, buf in st.momentum.items():
        np.testing.assert_array_equal(np.asarray(buf),
                                      np.asarray(back.momentum[skey]))
        np.testing.assert_array_equal(
            _logical_rows(plan4, "w", buf),
            _logical_rows(plan2, "w", st2.momentum[skey]))


def test_reshard_carries_variant_state():
    """NorMuon moments / MuonBP polar caches / Dion2 factor bases / AdaMuon
    second moments are owner-major buffers too and must reshard row-exactly
    with the momentum."""
    for variant in ("normuon", "muonbp", "dion2", "adamuon"):
        params, plan4 = _stack_plan(4)
        _, plan2 = _stack_plan(2)
        opt4 = api.Muon(plan4, config=MuonConfig(variant=variant))
        st = opt4.init(params)
        grads = jax.tree.map(
            lambda x: jax.random.normal(jax.random.PRNGKey(5), x.shape) * 0.1,
            params)
        _, st = opt4.update(grads, st, params)
        assert st.variant_state is not None

        st2 = reshard_owner_state(st, plan4, plan2)
        back = reshard_owner_state(st2, plan2, plan4)
        # structure must match a fresh init at the new plan (stateless
        # 'inner' fields stay None, not {}), or sharding templates built
        # from init_state would mismatch the resharded tree
        opt2 = api.Muon(plan2, config=MuonConfig(variant=variant))
        assert jax.tree_util.tree_structure(st2.variant_state) == \
            jax.tree_util.tree_structure(opt2.init(params).variant_state)
        for field, bufs in st.variant_state.items():
            for skey, buf in (bufs or {}).items():
                # logical rows are exactly preserved across D=4 -> 2 -> 4;
                # pad rows are reset to zero (they are never consumed —
                # e.g. MuonBP's NS of a zero pad matrix caches a nonzero
                # (∏a)·I polar map, which the repack rightfully drops)
                np.testing.assert_array_equal(
                    _logical_rows(plan4, "w", buf),
                    _logical_rows(plan2, "w",
                                  st2.variant_state[field][skey]))
                np.testing.assert_array_equal(
                    _logical_rows(plan4, "w", buf),
                    _logical_rows(plan4, "w",
                                  back.variant_state[field][skey]))
                g4 = plan4.groups["w"]
                pads = np.delete(np.asarray(back.variant_state[field][skey]),
                                 g4.unpack_index, axis=0)
                assert np.all(pads == 0)


def test_reshard_group_count_mismatch_raises():
    """Plans over different parameter sets must be rejected with a typed
    error naming the offending group and both counts — a bare assert would
    vanish under ``python -O`` and silently scramble rows."""
    import pytest

    params6, plan6 = _stack_plan(2)
    params4 = {"w": jax.random.normal(jax.random.PRNGKey(1), (4, 8, 24))}
    plan4mat = api.dedicate_params(params4, num_owners=2, strategy="greedy")
    opt = api.Muon(plan6, config=MuonConfig())
    st = opt.init(params6)
    with pytest.raises(ValueError) as ei:
        reshard_owner_state(st, plan6, plan4mat)
    msg = str(ei.value)
    assert "'w'" in msg and "6" in msg and "4" in msg
