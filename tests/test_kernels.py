"""Per-kernel validation: Pallas (interpret=True) vs pure-jnp oracles.

Sweeps shapes (aligned / unaligned / tiny / rectangular), dtypes, and block
sizes, asserting allclose against ref.py per the deliverable spec.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.symmul import tri_index_tables

SHAPES_SQUARE = [(1, 16), (2, 64), (3, 128), (2, 160), (1, 200), (4, 96)]
SHAPES_RECT = [(1, 16, 64), (2, 64, 256), (2, 96, 40), (1, 128, 384),
               (3, 32, 32), (1, 200, 72)]
DTYPES = [jnp.float32, jnp.bfloat16]
BLOCKS = [(64, 64), (128, 128), (128, 64)]


def _sym(shape, seed, dtype):
    a = jax.random.normal(jax.random.PRNGKey(seed), shape, dtype=jnp.float32)
    return ((a + a.mT) / 2).astype(dtype)


def _tol(dtype):
    # blocked accumulation order differs from XLA's dot — allow small noise
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=1e-4, atol=1e-3)


@pytest.mark.parametrize("batch,m", SHAPES_SQUARE)
@pytest.mark.parametrize("dtype", DTYPES)
def test_symmul_matches_ref(batch, m, dtype):
    a = _sym((batch, m, m), 0, dtype)
    b = _sym((batch, m, m), 1, dtype)
    # commuting not required for C = A@B correctness of the raw product —
    # the kernel computes the true lower blocks; mirror assumes symmetry, so
    # use powers of one matrix (guaranteed symmetric product).
    b = ref.symmul_ref(a, a)  # A² is symmetric; A and A² commute
    got = ops.symmul(a, b, block_m=64, block_k=64, interpret=True)
    want = ref.symmul_ref(a.astype(jnp.float32), b.astype(jnp.float32))
    # In finite precision A·B is only *approximately* symmetric (quantized B
    # no longer exactly commutes with A); the kernel mirrors the lower
    # triangle, i.e. symmetrizes.  Compare against the symmetrized reference.
    want = ref.mirror_lower(want)
    got = ref.mirror_lower(jnp.asarray(got, jnp.float32))
    np.testing.assert_allclose(np.asarray(got, np.float32), np.asarray(want),
                               **_tol(dtype))


@pytest.mark.parametrize("batch,m,n", SHAPES_RECT)
@pytest.mark.parametrize("dtype", DTYPES)
def test_syrk_matches_ref(batch, m, n, dtype):
    x = jax.random.normal(jax.random.PRNGKey(2), (batch, m, n), dtype=jnp.float32)
    x = x.astype(dtype)
    got = ops.syrk(x, block_m=64, block_k=64, interpret=True)
    want = ref.syrk_ref(x.astype(jnp.float32))
    np.testing.assert_allclose(np.asarray(got, np.float32), np.asarray(want),
                               **_tol(dtype))


@pytest.mark.parametrize("m", [32, 128, 160])
@pytest.mark.parametrize("coeffs", [(3.4445, -4.775, 2.0315), (8.287, -23.6, 17.3)])
def test_gram_poly_fused_epilogue(m, coeffs):
    g = _sym((2, m, m), 4, jnp.float32)
    a, b, c = coeffs
    got = ops.gram_poly(g, a, b, c, block_m=64, block_k=64, interpret=True)
    want = ref.gram_poly_ref(g, a, b, c)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("bm,bk", BLOCKS)
def test_block_size_invariance(bm, bk):
    a = _sym((2, 256, 256), 5, jnp.float32)
    want = ref.symmul_ref(a, a)
    got = ops.symmul(a, a, block_m=bm, block_k=bk, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_unaligned_padding_roundtrip():
    """Shapes not divisible by the block size must still be exact."""
    x = jax.random.normal(jax.random.PRNGKey(6), (2, 100, 212))
    got = ops.syrk(x, block_m=64, block_k=64, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref.syrk_ref(x)),
                               rtol=1e-5, atol=1e-5)


def test_tri_index_tables():
    ii, jj = tri_index_tables(4)
    assert len(ii) == 10
    assert all(j <= i for i, j in zip(ii, jj))
    # covers exactly the lower triangle
    assert sorted(zip(ii.tolist(), jj.tolist())) == \
        [(i, j) for i in range(4) for j in range(i + 1)]


def test_mirror_lower():
    raw = jnp.arange(16.0).reshape(1, 4, 4) + jnp.triu(
        jnp.full((4, 4), jnp.nan), 1)  # garbage above diagonal
    out = np.asarray(ref.mirror_lower(raw))
    assert not np.isnan(out).any()
    np.testing.assert_allclose(out, out.transpose(0, 2, 1))


def test_gram_ns_end_to_end_with_kernels():
    """Full Gram NS through the Pallas path == jnp path == standard NS."""
    from repro.core.gram_ns import GramNSConfig, gram_newton_schulz
    from repro.core.newton_schulz import newton_schulz
    m = jax.random.normal(jax.random.PRNGKey(7), (3, 64, 192))
    cfg_k = GramNSConfig(num_steps=5, use_kernels=True, kernel_interpret=True,
                         block_m=64, block_k=64)
    cfg_j = GramNSConfig(num_steps=5)
    got_k = gram_newton_schulz(m, cfg_k, assume_short_fat=True)
    got_j = gram_newton_schulz(m, cfg_j, assume_short_fat=True)
    want = newton_schulz(m, num_steps=5)
    np.testing.assert_allclose(np.asarray(got_k), np.asarray(got_j),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(got_k), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


def test_autotune_cache_roundtrip(tmp_path):
    from repro.kernels import autotune
    autotune.clear_memory_cache()
    path = str(tmp_path / "cache.json")
    bm, bk = autotune.tune("symmul", 512, 512, "float32",
                           backend="analytical", cache_path=path)
    assert bm % 8 == 0 and bk % 8 == 0
    # second lookup is a pure cache hit (same result, file persisted)
    assert autotune.lookup("symmul", 512, 512, "float32", cache_path=path) == (bm, bk)
    autotune.clear_memory_cache()
    assert autotune.lookup("symmul", 512, 512, "float32", cache_path=path) == (bm, bk)
    autotune.clear_memory_cache()


def test_autotune_candidates_respect_vmem():
    from repro.kernels import autotune
    for bm, bk in autotune.candidate_blocks(2048, 2048, 4):
        ws = (2 * (bm * bk + bk * bm) + 2 * bm * bm) * 4
        assert ws <= autotune._VMEM_BYTES * autotune._VMEM_FRACTION
