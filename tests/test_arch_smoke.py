"""Per-architecture smoke tests (deliverable f): reduced configs of the same
family — one forward/train step on CPU asserting output shapes + no NaNs,
plus prefill/decode cache-consistency against the training forward.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.configs.shapes import cell_supported
from repro.models import model_fns

ARCHS = list(configs.ARCH_IDS)
B, S = 2, 16


def _inputs(cfg, key):
    ks = jax.random.split(key, 3)
    out = {"tokens": jax.random.randint(ks[0], (B, S), 0, cfg.vocab)}
    if cfg.frontend == "patch":
        out["patches"] = jax.random.normal(
            ks[1], (B, cfg.frontend_len, cfg.frontend_dim)) * 0.1
    if cfg.encdec:
        out["frames"] = jax.random.normal(
            ks[2], (B, S, cfg.frontend_dim)) * 0.1
    elif cfg.frontend == "frame":
        out["frames"] = jax.random.normal(
            ks[2], (B, cfg.frontend_len, cfg.frontend_dim)) * 0.1
    return out


@pytest.fixture(scope="module", params=ARCHS)
def arch(request):
    cfg = configs.get(request.param, reduced=True)
    m = model_fns(cfg)
    params = jax.jit(lambda k: m.init(cfg, k))(jax.random.PRNGKey(0))
    return cfg, m, params


def test_forward_shapes_and_finite(arch):
    cfg, m, params = arch
    inp = _inputs(cfg, jax.random.PRNGKey(1))
    logits = jax.jit(lambda p, i: m.forward(cfg, p, **i))(params, inp)
    assert logits.shape == (B, S, cfg.vocab)
    assert logits.dtype == jnp.float32
    assert bool(jnp.isfinite(logits).all()), f"{cfg.name}: non-finite logits"


def test_train_step_grads_finite(arch):
    cfg, m, params = arch
    inp = _inputs(cfg, jax.random.PRNGKey(2))
    labels = jax.random.randint(jax.random.PRNGKey(3), (B, S), 0, cfg.vocab)

    def loss_fn(p):
        logits = m.forward(cfg, p, **inp)
        lp = jax.nn.log_softmax(logits, axis=-1)
        return -jnp.mean(jnp.take_along_axis(lp, labels[..., None], -1))

    loss, grads = jax.jit(jax.value_and_grad(loss_fn))(params)
    assert bool(jnp.isfinite(loss)), cfg.name
    for path, g in jax.tree_util.tree_leaves_with_path(grads):
        assert bool(jnp.isfinite(g).all()), (cfg.name, path)
    # at least the embedding and some block weight receive nonzero gradient
    total = sum(float(jnp.sum(jnp.abs(g))) for g in jax.tree.leaves(grads))
    assert total > 0


def test_prefill_decode_matches_forward(arch):
    """prefill(t[:S]) logits == forward(t)[:, S-1]; then one decode step
    equals forward on the extended sequence — validates every cache path."""
    cfg, m, params = arch
    inp = _inputs(cfg, jax.random.PRNGKey(4))
    tokens = inp.pop("tokens")
    max_len = S + 4

    full = m.forward(cfg, params, tokens, **inp)
    prefix = 0
    if cfg.encdec:
        logits_p, cache = m.prefill(cfg, params, tokens,
                                    frames=inp["frames"], max_len=max_len,
                                    cache_dtype=jnp.float32)
    elif cfg.family == "ssm":
        logits_p, cache = m.prefill(cfg, params, tokens, max_len)
    else:
        prefix = cfg.frontend_len if cfg.frontend is not None else 0
        logits_p, cache = m.prefill(cfg, params, tokens,
                                    max_len + prefix,
                                    cache_dtype=jnp.float32, **inp)
    np.testing.assert_allclose(np.asarray(logits_p),
                               np.asarray(full[:, -1]), rtol=2e-3, atol=2e-3,
                               err_msg=f"{cfg.name}: prefill != forward")

    nxt = jnp.argmax(logits_p, -1).astype(jnp.int32)
    logits_d, _ = m.decode_step(cfg, params, nxt, cache,
                                jnp.asarray(S + prefix, jnp.int32))
    ext = jnp.concatenate([tokens, nxt[:, None]], 1)
    full2 = m.forward(cfg, params, ext, **inp)
    np.testing.assert_allclose(np.asarray(logits_d),
                               np.asarray(full2[:, -1]), rtol=5e-3, atol=5e-3,
                               err_msg=f"{cfg.name}: decode != forward")


def test_decode_vector_pos_matches_scalar(arch):
    """decode_step with a (B,) position vector (continuous-batching slots)
    is BITWISE identical to the scalar-pos path when all rows share the
    position — the serving tier's per-slot decode rides this guarantee."""
    cfg, m, params = arch
    inp = _inputs(cfg, jax.random.PRNGKey(5))
    tokens = inp.pop("tokens")
    max_len = S + 4

    prefix = 0
    if cfg.encdec:
        _, cache = m.prefill(cfg, params, tokens, frames=inp["frames"],
                             max_len=max_len, cache_dtype=jnp.float32)
    elif cfg.family == "ssm":
        _, cache = m.prefill(cfg, params, tokens, max_len)
    else:
        prefix = cfg.frontend_len if cfg.frontend is not None else 0
        _, cache = m.prefill(cfg, params, tokens, max_len + prefix,
                             cache_dtype=jnp.float32, **inp)

    nxt = jnp.full((B,), 7, jnp.int32)
    d_s, cache_s = m.decode_step(cfg, params, nxt, cache,
                                 jnp.asarray(S + prefix, jnp.int32))
    d_v, cache_v = m.decode_step(cfg, params, nxt, cache,
                                 jnp.full((B,), S + prefix, jnp.int32))
    assert np.array_equal(np.asarray(d_s), np.asarray(d_v)), cfg.name
    for (path, a), (_, b) in zip(
            jax.tree_util.tree_leaves_with_path(cache_s),
            jax.tree_util.tree_leaves_with_path(cache_v)):
        assert np.array_equal(np.asarray(a), np.asarray(b)), (cfg.name, path)


def test_long_500k_eligibility_rule():
    eligible = {a for a in ARCHS
                if cell_supported(configs.get(a, reduced=True),
                                  "long_500k") is None}
    assert eligible == {"hymba-1.5b", "xlstm-350m"}


def test_registry_covers_assignment():
    assert set(ARCHS) == {
        "hymba-1.5b", "qwen2.5-14b", "nemotron-4-340b", "smollm-360m",
        "stablelm-1.6b", "deepseek-v3-671b", "kimi-k2-1t-a32b", "xlstm-350m",
        "seamless-m4t-large-v2", "llava-next-mistral-7b"}


def test_full_configs_match_assignment_table():
    rows = {
        "hymba-1.5b": (32, 1600, 25, 5, 5504, 32001),
        "qwen2.5-14b": (48, 5120, 40, 8, 13824, 152064),
        "nemotron-4-340b": (96, 18432, 96, 8, 73728, 256000),
        "smollm-360m": (32, 960, 15, 5, 2560, 49152),
        "stablelm-1.6b": (24, 2048, 32, 32, 5632, 100352),
        "deepseek-v3-671b": (61, 7168, 128, 128, 2048, 129280),
        "kimi-k2-1t-a32b": (61, 7168, 64, 8, 2048, 163840),
        "xlstm-350m": (24, 1024, 4, 4, 0, 50304),
        "seamless-m4t-large-v2": (24, 1024, 16, 16, 8192, 256206),
        "llava-next-mistral-7b": (32, 4096, 32, 8, 14336, 32000),
    }
    for a, (L, d, H, KV, ff, V) in rows.items():
        cfg = configs.get(a)
        assert (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                cfg.d_ff, cfg.vocab) == (L, d, H, KV, ff, V), a
    # family-specific extras
    assert configs.get("deepseek-v3-671b").moe.n_experts == 256
    assert configs.get("deepseek-v3-671b").moe.top_k == 8
    assert configs.get("deepseek-v3-671b").attn_kind == "mla"
    assert configs.get("kimi-k2-1t-a32b").moe.n_experts == 384
    assert configs.get("hymba-1.5b").ssm.d_state == 16
