"""End-to-end behaviour tests for the DMuon system (paper-level invariants)."""

import glob
import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.core import api
from repro.core.muon import MuonConfig
from repro.data.pipeline import DataConfig, batch_for_step
from repro.models import model_fns
from repro.train.step import init_state, make_train_step

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_three_line_api_end_to_end():
    """Paper Fig. 1(a): dedicate_params + Muon + update drives a real model."""
    cfg = configs.get("smollm-360m", reduced=True)
    shapes = jax.eval_shape(lambda k: model_fns(cfg).init(cfg, k),
                            jax.random.PRNGKey(0))
    plan = api.dedicate_params(shapes)                    # line 1
    opt = api.Muon(plan, config=MuonConfig())             # line 2
    state = init_state(cfg, opt, jax.random.PRNGKey(0))   # line 3 (init)
    step = make_train_step(cfg, opt, donate=False)
    dcfg = DataConfig(vocab=cfg.vocab, seq_len=32, global_batch=4)
    l0 = None
    for i in range(8):
        state = step(state, batch_for_step(dcfg, i))
        if l0 is None:
            l0 = float(state.loss_ema)
    assert np.isfinite(float(state.loss_ema))
    assert float(state.loss_ema) < l0            # learning


def test_muon_semantics_invariant_across_strategies():
    """Ownership strategy changes scheduling, never the update (paper §3.4:
    'preserving exact optimizer semantics')."""
    cfg = configs.get("smollm-360m", reduced=True, n_layers=2)
    m = model_fns(cfg)
    params = m.init(cfg, jax.random.PRNGKey(0))
    grads = jax.tree.map(
        lambda x: jax.random.normal(jax.random.PRNGKey(1), x.shape) * 0.01,
        params)
    outs = []
    for strat in ("greedy", "round_robin", "rank0"):
        plan = api.dedicate_params(params, num_owners=4, strategy=strat)
        opt = api.Muon(plan, config=MuonConfig())
        st = opt.init(params)
        upd, _ = opt.update(grads, st, params)
        outs.append(upd)
    for other in outs[1:]:
        for a, b in zip(jax.tree.leaves(outs[0]), jax.tree.leaves(other)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-4, atol=1e-5)


def test_dryrun_artifacts_complete_and_green():
    """Deliverable e/g: every (arch × shape × mesh) cell recorded; runnable
    cells ok; skips only via the sub-quadratic rule; roofline terms present."""
    base = os.path.join(ROOT, "experiments", "dryrun")
    if not os.path.isdir(base):
        import pytest
        pytest.skip("dry-run artifacts not generated in this checkout")
    for mesh in ("single", "multi"):
        files = glob.glob(os.path.join(base, mesh, "*.json"))
        assert len(files) == 40, (mesh, len(files))
        for fp in files:
            with open(fp) as f:
                d = json.load(f)
            if d.get("skipped"):
                assert d["shape"] == "long_500k"
                continue
            assert d.get("ok"), (fp, d.get("error"))
            r = d["roofline"]
            assert r["compute_s"] >= 0 and r["memory_s"] > 0
            assert r["dominant"] in ("compute", "memory", "collective")
            assert d["memory_analysis"]["total_bytes"] > 0
