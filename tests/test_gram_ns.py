"""Gram Newton-Schulz correctness: agreement with standard NS and SVD oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.coefficients import POLAR_EXPRESS, get_coefficients
from repro.core.gram_ns import GramNSConfig, gram_newton_schulz, gram_ns_flops
from repro.core.newton_schulz import msign_svd, newton_schulz

jax.config.update("jax_enable_x64", False)


def _rand(shape, seed=0, dtype=jnp.float32):
    return jax.random.normal(jax.random.PRNGKey(seed), shape, dtype=dtype)


@pytest.mark.parametrize("shape", [(16, 16), (16, 64), (64, 16), (48, 80),
                                   (8, 256), (100, 36)])
@pytest.mark.parametrize("schedule", ["polar_express", "quintic"])
def test_gram_matches_standard_ns(shape, schedule):
    m = _rand(shape)
    ref = newton_schulz(m, num_steps=5, schedule=schedule)
    got = gram_newton_schulz(m, GramNSConfig(num_steps=5, schedule=schedule))
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("shape", [(32, 32), (24, 96), (96, 24)])
def test_ns_approximates_polar_factor(shape):
    m = _rand(shape, seed=3)
    exact = msign_svd(m)
    for fn in (lambda x: newton_schulz(x, num_steps=8),
               lambda x: gram_newton_schulz(x, GramNSConfig(num_steps=8))):
        got = fn(m)
        np.testing.assert_allclose(np.asarray(got), np.asarray(exact),
                                   rtol=0, atol=5e-2)


def test_singular_values_driven_to_one():
    m = _rand((40, 120), seed=7)
    out = gram_newton_schulz(m, GramNSConfig(num_steps=8))
    s = jnp.linalg.svd(out.astype(jnp.float32), compute_uv=False)
    assert float(jnp.max(jnp.abs(s - 1.0))) < 5e-2


def test_batched_matches_loop():
    stack = _rand((6, 24, 48), seed=1)
    cfg = GramNSConfig(num_steps=5)
    batched = gram_newton_schulz(stack, cfg, assume_short_fat=True)
    for i in range(stack.shape[0]):
        single = gram_newton_schulz(stack[i], cfg)
        np.testing.assert_allclose(np.asarray(batched[i]), np.asarray(single),
                                   rtol=1e-4, atol=1e-4)


def test_orthogonality_of_output():
    m = _rand((32, 128), seed=11)
    o = gram_newton_schulz(m, GramNSConfig(num_steps=8))
    gram = np.asarray(o @ o.T)
    np.testing.assert_allclose(gram, np.eye(32), atol=8e-2)


def test_bf16_input_supported():
    m = _rand((32, 64), seed=5).astype(jnp.bfloat16)
    out = gram_newton_schulz(m, GramNSConfig(num_steps=5))
    assert out.dtype == jnp.bfloat16
    ref = newton_schulz(m.astype(jnp.float32), num_steps=5)
    np.testing.assert_allclose(np.asarray(out, dtype=np.float32),
                               np.asarray(ref), atol=5e-2)


def test_coefficient_schedules():
    sched = get_coefficients("polar_express", 10)
    assert len(sched) == 10
    assert sched[:8] == POLAR_EXPRESS
    assert sched[9] == POLAR_EXPRESS[-1]
    q = get_coefficients("quintic", 5)
    assert all(c == (3.4445, -4.7750, 2.0315) for c in q)
    with pytest.raises(ValueError):
        get_coefficients("nope", 5)


def test_flop_model_sane():
    f = gram_ns_flops(1024, 4096, num_steps=5, batch=2)
    # Gram-space must beat standard NS for fat matrices, symmetric halves it.
    assert f["gram_full_gemm"] < f["standard_ns"]
    assert f["gram_symmetric_kernel"] < f["gram_full_gemm"]
    # At square shapes Gram-space only wins WITH the symmetric kernels
    # (11.5 vs 15 m³-units) — full-GEMM Gram is more FLOPs than standard NS.
    sq = gram_ns_flops(512, 512)
    assert sq["gram_symmetric_kernel"] < sq["standard_ns"] < sq["gram_full_gemm"]
