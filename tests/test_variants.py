"""The pluggable variant registry: NorMuon, MuonBP, Dion2, AdaMuon, AdamW —
all sharing the owner-layout pipeline, differing only in the orthogonalizer
backend and its per-group state (threaded through MuonState.variant_state)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import api
from repro.core.gram_ns import GramNSConfig
from repro.core.muon import MuonConfig


def _tree(seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 6)
    return {
        "blocks": {
            "wq": jax.random.normal(ks[0], (3, 32, 32)) * 0.02,
            "up": jax.random.normal(ks[2], (3, 32, 128)) * 0.02,
            "down": jax.random.normal(ks[3], (3, 128, 32)) * 0.02,
            "norm_scale": jnp.ones((3, 32)),
        },
        "embed_table": jax.random.normal(ks[4], (100, 32)) * 0.02,
    }


def _grads(seed=1):
    return jax.tree.map(
        lambda x: jax.random.normal(jax.random.PRNGKey(seed + x.size % 97),
                                    x.shape) * 0.1, _tree())


def _mk(variant, **kw):
    params = _tree()
    plan = api.dedicate_params(params, num_owners=4, strategy="greedy")
    kw.setdefault("ns", GramNSConfig(num_steps=5))
    cfg = MuonConfig(variant=variant, learning_rate=0.1, momentum=0.9, **kw)
    return params, plan, api.Muon(plan, config=cfg)


def _run(opt, params, n=3):
    state = opt.init(params)
    for t in range(n):
        u, state = opt.update(_grads(seed=t), state, params)
        params = jax.tree.map(lambda p, d: p + d, params, u)
    return params, state


# ------------------------------------------------------------------ registry

def test_registry_contents_and_errors():
    assert set(api.VARIANTS) >= {"muon", "normuon", "muonbp", "dion2",
                                 "adamuon", "adamw"}
    with pytest.raises(ValueError, match="unknown variant"):
        api.get_variant("dion3")
    with pytest.raises(ValueError, match="already registered"):
        api.register_variant(api.VARIANTS["muon"])
    params, plan, _ = _mk("muon")
    with pytest.raises(ValueError, match="unknown variant"):
        api.Muon(plan, config=MuonConfig(variant="nope"))


def test_known_orthogonalizers_single_source_of_truth():
    """Every advertised backend name constructs, and the unknown-name error
    lists exactly the advertised set (incl. the gram_auto alias and the
    composed normuon/adamuon names the old hand-written list omitted)."""
    from repro.core.orthogonalize import (Orthogonalizer,
                                          known_orthogonalizers,
                                          make_orthogonalizer)
    cfg = MuonConfig()
    names = known_orthogonalizers()
    assert {"auto", "gram_auto", "normuon", "adamuon", "dion2",
            "block_periodic"} <= set(names)
    for name in names:
        assert isinstance(make_orthogonalizer(name, cfg), Orthogonalizer)
    with pytest.raises(ValueError) as ei:
        make_orthogonalizer("definitely_not_a_backend", cfg)
    for name in names:
        assert name in str(ei.value)


def test_gather_mode_rejects_variant_backends():
    params, plan, _ = _mk("muon")
    opt = api.Muon(plan, config=MuonConfig(variant="normuon", mode="gather"))
    with pytest.raises(ValueError, match="owner pipeline"):
        opt.init(params)


def test_adamw_variant_equals_adamw_mode():
    params, _, opt_v = _mk("adamw")
    _, _, opt_m = _mk("muon", mode="adamw")
    uv, _ = opt_v.update(_grads(), opt_v.init(params), params)
    um, _ = opt_m.update(_grads(), opt_m.init(params), params)
    for a, b in zip(jax.tree.leaves(uv), jax.tree.leaves(um)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ------------------------------------------------------------------- muonbp

def test_muonbp_period_one_matches_muon_exactly():
    """Every step refreshes -> bit-identical to the plain Gram path."""
    params_m, _, opt_m = _mk("muon")
    params_b, _, opt_b = _mk("muonbp", muonbp_period=1)
    sm, sb = opt_m.init(params_m), opt_b.init(params_b)
    for t in range(3):
        g = _grads(seed=t)
        um, sm = opt_m.update(g, sm, params_m)
        ub, sb = opt_b.update(g, sb, params_b)
        for a, b in zip(jax.tree.leaves(um), jax.tree.leaves(ub)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        params_m = jax.tree.map(lambda p, u: p + u, params_m, um)
        params_b = jax.tree.map(lambda p, u: p + u, params_b, ub)


def test_muonbp_caches_and_reuses_polar_map():
    params, plan, opt = _mk("muonbp", muonbp_period=3)
    state = opt.init(params)
    g = _grads()
    # step 0: refresh — Q cache becomes nonzero
    _, s1 = opt.update(g, state, params)
    q1 = {k: np.asarray(v) for k, v in s1.variant_state["q"].items()}
    assert all(np.abs(q).max() > 0 for q in q1.values())
    # steps 1, 2: reuse — the cache must be carried through unchanged
    _, s2 = opt.update(g, s1, params)
    _, s3 = opt.update(g, s2, params)
    for k in q1:
        np.testing.assert_array_equal(q1[k],
                                      np.asarray(s3.variant_state["q"][k]))
    # step 3: refresh again — momentum changed, so the cache must move
    _, s4 = opt.update(g, s3, params)
    assert any(
        np.abs(q1[k] - np.asarray(s4.variant_state["q"][k])).max() > 1e-7
        for k in q1)


def test_muonbp_reuse_step_is_finite_and_reasonable():
    """In-between steps apply a stale polar map — still a descent-scaled,
    finite update of the same magnitude class as the exact one."""
    params, _, opt = _mk("muonbp", muonbp_period=2)
    params_m, _, opt_m = _mk("muon")
    sb, sm = opt.init(params), opt_m.init(params_m)
    g = _grads()
    _, sb = opt.update(g, sb, params)            # refresh
    ub, _ = opt.update(g, sb, params)            # reuse (stale Q)
    _, sm = opt_m.update(g, sm, params_m)
    um, _ = opt_m.update(g, sm, params_m)        # exact
    for a, b in zip(jax.tree.leaves(ub), jax.tree.leaves(um)):
        a, b = np.asarray(a, np.float32), np.asarray(b, np.float32)
        assert np.isfinite(a).all()
        assert np.linalg.norm(a) < 10 * np.linalg.norm(b) + 1e-6


# ------------------------------------------------------------------ normuon

def test_normuon_state_shapes_and_finiteness():
    params, plan, opt = _mk("normuon")
    new_params, state = _run(opt, params)
    v = state.variant_state["v"]
    for key, grp in plan.groups.items():
        skey = key.replace("/", ".")
        assert v[skey].shape == (grp.packed_size, grp.key[0])
        assert np.isfinite(np.asarray(v[skey])).all()
        # pad rows never receive updates
        if grp.packed_size > grp.count:
            assert np.all(np.asarray(v[skey])[grp.count:] == 0)
    for leaf in jax.tree.leaves(new_params):
        assert np.isfinite(np.asarray(leaf)).all()


def test_normuon_differs_from_muon_but_preserves_update_norm():
    params_n, _, opt_n = _mk("normuon")
    params_m, _, opt_m = _mk("muon")
    g = _grads()
    un, _ = opt_n.update(g, opt_n.init(params_n), params_n)
    um, _ = opt_m.update(g, opt_m.init(params_m), params_m)
    wq_n = np.asarray(un["blocks"]["wq"], np.float32)
    wq_m = np.asarray(um["blocks"]["wq"], np.float32)
    assert np.abs(wq_n - wq_m).max() > 1e-6       # it does something
    np.testing.assert_allclose(                   # but keeps the magnitude
        np.linalg.norm(wq_n), np.linalg.norm(wq_m), rtol=0.05)


def test_variants_compose_with_bucket_fusion():
    params, _, opt = _mk("normuon", ns=GramNSConfig(num_steps=5,
                                                    bucket_fusion=True))
    new_params, state = _run(opt, params, n=2)
    for leaf in jax.tree.leaves(new_params):
        assert np.isfinite(np.asarray(leaf)).all()


# -------------------------------------------------------------------- dion2

def test_dion2_state_shapes_and_rank():
    from repro.core.orthogonalize import dion2_rank
    params, plan, opt = _mk("dion2", dion2_rank_frac=0.25)
    new_params, state = _run(opt, params, n=2)
    q = state.variant_state["q"]
    for key, grp in plan.groups.items():
        skey = key.replace("/", ".")
        m = grp.key[0]
        r = dion2_rank(m, opt.config)
        assert 1 <= r <= m
        assert q[skey].shape == (grp.packed_size, m, r)
        assert np.isfinite(np.asarray(q[skey])).all()
    for leaf in jax.tree.leaves(new_params):
        assert np.isfinite(np.asarray(leaf)).all()


def test_dion2_cold_start_is_leading_row_submatrix():
    """A cold (all-zero) basis falls back to the leading-r row selector, so
    the first update is exactly √(m/r)·NS(M[:r]) lifted back into rows 0..r
    — the literal 'shrink the matrix' step."""
    from repro.core.gram_ns import gram_newton_schulz
    from repro.core.orthogonalize import Dion2GramNS
    from repro.core.owner_comms import OwnerLayout, group_key_str
    m, n, r = 16, 48, 4
    x = jax.random.normal(jax.random.PRNGKey(0), (4, m, n)) * 0.02
    plan = api.dedicate_params({"w": x}, num_owners=1, strategy="greedy")
    cfg = MuonConfig(variant="dion2", dion2_rank_frac=r / m,
                     ns=GramNSConfig(num_steps=5))
    layout = OwnerLayout(plan)
    ortho = Dion2GramNS()
    state = ortho.init_state(layout, cfg)
    skey = group_key_str("w")
    assert np.all(np.asarray(state["q"][skey]) == 0)
    out, state1 = ortho({skey: x}, step=jnp.zeros((), jnp.int32),
                        state=state, layout=layout, cfg=cfg)
    u = np.asarray(out[skey], np.float32)
    np.testing.assert_allclose(u[:, r:, :], 0.0, atol=1e-6)
    ref = np.asarray(gram_newton_schulz(x[:, :r, :], cfg=cfg.ns,
                                        assume_short_fat=True))
    np.testing.assert_allclose(u[:, :r, :], ref * np.sqrt(m / r),
                               rtol=1e-4, atol=1e-5)
    # the basis is warm now and moves off the axis-aligned selector
    out2, state2 = ortho({skey: x}, step=jnp.ones((), jnp.int32),
                         state=state1, layout=layout, cfg=cfg)
    u2 = np.asarray(out2[skey], np.float32)
    assert np.abs(u2[:, r:, :]).max() > 1e-5   # full rows participate now
    assert np.abs(np.asarray(state2["q"][skey])
                  - np.asarray(state1["q"][skey])).max() > 1e-6


def test_dion2_full_rank_approximates_muon():
    """r = m removes the shrinking, so dion2 must agree with plain muon up
    to the basis rotation (NS on QᵀM vs M — same polar limit)."""
    params_d, _, opt_d = _mk("dion2", dion2_rank_frac=1.0)
    params_m, _, opt_m = _mk("muon")
    g = _grads()
    ud, _ = opt_d.update(g, opt_d.init(params_d), params_d)
    um, _ = opt_m.update(g, opt_m.init(params_m), params_m)
    for a, b in zip(jax.tree.leaves(ud), jax.tree.leaves(um)):
        a, b = np.asarray(a, np.float32), np.asarray(b, np.float32)
        assert np.linalg.norm(a - b) < 1e-2 * np.linalg.norm(b) + 1e-8


def test_dion2_rank_frac_validation():
    from repro.core.orthogonalize import dion2_rank
    params, _, opt = _mk("dion2", dion2_rank_frac=0.0)
    with pytest.raises(ValueError, match="dion2_rank_frac"):
        opt.init(params)
    cfg = MuonConfig(dion2_rank_frac=0.25)
    assert dion2_rank(32, cfg) == 8
    assert dion2_rank(1, cfg) == 1          # floors at rank 1
    assert dion2_rank(32, MuonConfig(dion2_rank_frac=1.0)) == 32


# ------------------------------------------------------------------ adamuon

def test_adamuon_state_shapes_and_pad_rows():
    params, plan, opt = _mk("adamuon")
    new_params, state = _run(opt, params)
    v = state.variant_state["v"]
    assert state.variant_state["inner"] is None   # base Gram is stateless
    for key, grp in plan.groups.items():
        skey = key.replace("/", ".")
        m, n = grp.key
        assert v[skey].shape == (grp.packed_size, m, n)
        assert np.isfinite(np.asarray(v[skey])).all()
        # pad rows never receive updates (gram NS of a zero matrix is zero)
        if grp.packed_size > grp.count:
            assert np.all(np.asarray(v[skey])[grp.count:] == 0)
    for leaf in jax.tree.leaves(new_params):
        assert np.isfinite(np.asarray(leaf)).all()


def test_adamuon_differs_from_muon_but_preserves_update_norm():
    params_a, _, opt_a = _mk("adamuon")
    params_m, _, opt_m = _mk("muon")
    g = _grads()
    ua, _ = opt_a.update(g, opt_a.init(params_a), params_a)
    um, _ = opt_m.update(g, opt_m.init(params_m), params_m)
    wq_a = np.asarray(ua["blocks"]["wq"], np.float32)
    wq_m = np.asarray(um["blocks"]["wq"], np.float32)
    assert np.abs(wq_a - wq_m).max() > 1e-6       # it does something
    np.testing.assert_allclose(                   # but keeps the magnitude
        np.linalg.norm(wq_a), np.linalg.norm(wq_m), rtol=0.05)


@pytest.mark.parametrize("variant", ["dion2", "adamuon"])
def test_new_variants_compose_with_bucket_fusion(variant):
    params, _, opt = _mk(variant, ns=GramNSConfig(num_steps=5,
                                                  bucket_fusion=True))
    new_params, state = _run(opt, params, n=2)
    for leaf in jax.tree.leaves(new_params):
        assert np.isfinite(np.asarray(leaf)).all()


# ------------------------------------------------------- state round-trips

@pytest.mark.parametrize("variant", ["normuon", "muonbp", "dion2",
                                     "adamuon"])
def test_state_dict_roundtrip_with_variant_state(variant):
    params, _, opt = _mk(variant)
    _, state = _run(opt, params, n=2)
    d = opt.state_dict(state)
    assert d["variant_state"] is not None
    state2 = opt.load_state_dict(d)
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(state2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("variant", ["normuon", "muonbp", "dion2",
                                     "adamuon"])
def test_checkpoint_roundtrip_variant_state(tmp_path, variant):
    """The new per-variant state fields survive the checkpoint manager."""
    from repro.checkpoint.manager import CheckpointManager
    params, _, opt = _mk(variant)
    _, state = _run(opt, params, n=2)
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    mgr.save(2, state, block=True)
    restored = mgr.restore(2)
    assert jax.tree_util.tree_structure(restored) == \
        jax.tree_util.tree_structure(state)
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # and training continues from the restored state bit-identically
    u1, _ = opt.update(_grads(seed=9), state, params)
    u2, _ = opt.update(_grads(seed=9), restored, params)
    for a, b in zip(jax.tree.leaves(u1), jax.tree.leaves(u2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
