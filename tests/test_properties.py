"""Property-based tests (hypothesis) on the system's invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need the optional hypothesis dep "
    "(pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import load_balance as lb
from repro.core.gram_ns import GramNSConfig, gram_newton_schulz
from repro.core.layout import slot_sequence
from repro.core.newton_schulz import newton_schulz

_SETTINGS = dict(max_examples=15, deadline=None)


# --------------------------------------------------------- optimizer math

@settings(**_SETTINGS)
@given(m=st.integers(4, 24), n=st.integers(4, 48), seed=st.integers(0, 999))
def test_ns_drives_singular_values_to_one(m, n, seed):
    x = jax.random.normal(jax.random.PRNGKey(seed), (m, n))
    out = newton_schulz(x, num_steps=10)
    s = jnp.linalg.svd(out.astype(jnp.float32), compute_uv=False)
    # rank-deficient directions stay 0; everything else ~1
    s = s[s > 0.2]
    assert float(jnp.max(jnp.abs(s - 1.0))) < 0.1


@settings(**_SETTINGS)
@given(m=st.integers(4, 16), n=st.integers(16, 40), seed=st.integers(0, 999))
def test_gram_ns_equals_standard_ns(m, n, seed):
    x = jax.random.normal(jax.random.PRNGKey(seed), (m, n))
    a = newton_schulz(x, num_steps=5)
    b = gram_newton_schulz(x, GramNSConfig(num_steps=5))
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=5e-3)


@settings(**_SETTINGS)
@given(seed=st.integers(0, 999))
def test_ns_left_orthogonal_equivariance(seed):
    """NS(QM) == Q NS(M) for orthogonal Q — the polar factor is
    left-equivariant, so the owner may orthogonalize in any basis."""
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    m = jax.random.normal(k1, (12, 20))
    q, _ = jnp.linalg.qr(jax.random.normal(k2, (12, 12)))
    a = newton_schulz(q @ m, num_steps=8)
    b = q @ newton_schulz(m, num_steps=8)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=5e-3)


# ------------------------------------------------------------ assignment

@st.composite
def _census(draw):
    n_shapes = draw(st.integers(1, 4))
    out = {}
    for _ in range(n_shapes):
        m = draw(st.sampled_from([32, 64, 128, 256]))
        n = draw(st.sampled_from([64, 128, 512, 1024]))
        out[(min(m, n), max(m, n))] = draw(st.integers(1, 40))
    return out


@settings(**_SETTINGS)
@given(census=_census(), owners=st.integers(1, 16))
def test_assignment_covers_every_matrix_exactly_once(census, owners):
    cm = lb.analytic_cost_model(census)
    for strat in ("greedy", "lpt", "round_robin", "rank0"):
        asn = lb.assign(census, owners, strategy=strat, cost_model=cm)
        for s, count in census.items():
            assert len(asn.owner_of[s]) == count               # Eq. 5
            assert sum(b for b, _ in asn.chunks[s]) == count
            assert (asn.owner_of[s] < owners).all()
            assert (asn.owner_of[s] >= 0).all()


@settings(**_SETTINGS)
@given(census=_census(), owners=st.integers(2, 12))
def test_greedy_never_worse_than_rank0(census, owners):
    # batching-free cost model: with amortization, rank0's one mega-batch
    # can genuinely beat split chunks on tiny censuses (the batching×balance
    # interaction of §3.4) — the distribution property needs flat costs.
    cm = lb.analytic_cost_model(census, batch_sizes=(1,))
    g = lb.solve_greedy(census, cm, owners)
    r0 = lb.rank0(census, owners)
    assert g.makespan(cm) <= r0.makespan(cm) + 1e-12


@settings(**_SETTINGS)
@given(census=_census(), owners=st.integers(2, 8),
       slow=st.integers(0, 7), factor=st.floats(2.0, 8.0))
def test_speed_aware_rebalance_never_hurts(census, owners, slow, factor):
    """With a degraded owner, solving WITH the measured speeds never yields a
    worse speed-adjusted makespan than solving blind — under a batching-free
    cost model.  (With batch amortization the property is genuinely false:
    finer rebalancing granularity can cost more than it saves, the
    batching×balance interaction of §3.4 — hypothesis found the
    counterexample {(32,64):4}, 2 owners.)"""
    slow = slow % owners
    speed = np.ones(owners)
    speed[slow] = 1.0 / factor
    cm = lb.analytic_cost_model(census, batch_sizes=(1,))
    aware = lb.solve_greedy(census, cm, owners, speed=speed)
    blind = lb.solve_greedy(census, cm, owners)
    assert aware.makespan(cm, speed) <= blind.makespan(cm, speed) + 1e-12


# -------------------------------------------------------------- layout

@settings(**_SETTINGS)
@given(rows=st.sampled_from([2, 4, 8]), mult=st.sampled_from([1, 2, 4]),
       periods=st.integers(1, 3))
def test_xor_layout_balanced_for_divisible_meshes(rows, mult, periods):
    cols = rows * mult
    seq = slot_sequence(rows * cols * periods, rows, cols)
    counts = np.bincount(seq, minlength=rows * cols)
    assert counts.min() == counts.max() == periods
    # consecutive matrices never share a column
    if cols > 1:
        colseq = seq % cols
        assert all(colseq[i] != colseq[i + 1] for i in range(len(seq) - 1))


# -------------------------------------------------------- pack round trip

@settings(**_SETTINGS)
@given(l=st.integers(1, 6), m=st.sampled_from([8, 16]),
       n=st.sampled_from([8, 24]), owners=st.sampled_from([1, 2, 4]),
       seed=st.integers(0, 99))
def test_pack_unpack_roundtrip_random_shapes(l, m, n, owners, seed):
    from repro.core import api
    from repro.core.muon import pack_group, unpack_group
    params = {"w": jax.random.normal(jax.random.PRNGKey(seed), (l, m, n))}
    plan = api.dedicate_params(params, num_owners=owners, strategy="greedy")
    key = next(iter(plan.groups))
    packed = pack_group(plan, key, {"w": params["w"]})
    assert packed.shape[0] % owners == 0
    out = unpack_group(plan, key, packed)
    np.testing.assert_array_equal(np.asarray(out["w"]),
                                  np.asarray(params["w"]))


# ------------------------------------------------------------ cost model

@settings(**_SETTINGS)
@given(m=st.sampled_from([64, 256]), n=st.sampled_from([256, 1024]))
def test_cost_model_batching_amortization(m, n):
    cm = lb.analytic_cost_model({(m, n): 8}, batch_sizes=(1, 2, 4, 8))
    costs = [cm.cost((m, n), b) for b in (1, 2, 4, 8)]
    # total cost grows with batch size, per-matrix cost never increases
    assert all(c2 >= c1 - 1e-12 for c1, c2 in zip(costs, costs[1:]))
    per = [c / b for c, b in zip(costs, (1, 2, 4, 8))]
    assert all(p2 <= p1 + 1e-12 for p1, p2 in zip(per, per[1:]))
