"""Substrate tests: data pipeline determinism, checkpoint manager (atomic
commit / rotation / elastic restore), straggler monitor, end-to-end train
steps with loss decrease, and checkpoint-restart bit-exactness.
"""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.checkpoint.manager import CheckpointManager
from repro.core import api
from repro.core.muon import MuonConfig
from repro.data.pipeline import DataConfig, Pipeline, batch_for_step
from repro.runtime.elastic import StepTimer, StragglerMonitor, viable_mesh_shape
from repro.train.step import init_state, make_train_step


def test_pipeline_determinism_and_restart():
    cfg = DataConfig(vocab=100, seq_len=16, global_batch=4, seed=7)
    b3 = batch_for_step(cfg, 3)
    b3_again = batch_for_step(cfg, 3)
    np.testing.assert_array_equal(np.asarray(b3["tokens"]),
                                  np.asarray(b3_again["tokens"]))
    # streaming from step 3 yields the same batch as direct access
    pipe = Pipeline(cfg, start_step=3, prefetch=1)
    first = next(pipe)
    pipe.close()
    np.testing.assert_array_equal(np.asarray(first["tokens"]),
                                  np.asarray(b3["tokens"]))
    # labels are next-token shifted
    np.testing.assert_array_equal(np.asarray(b3["tokens"][:, 1:]),
                                  np.asarray(b3["labels"][:, :-1]))


def test_pipeline_has_learnable_structure():
    cfg = DataConfig(vocab=50, seq_len=256, global_batch=8, seed=1)
    b = batch_for_step(cfg, 0)
    toks, labs = np.asarray(b["tokens"]), np.asarray(b["labels"])
    # most transitions follow the deterministic table => repeated pairs
    pairs = {}
    for t, l in zip(toks.reshape(-1), labs.reshape(-1)):
        pairs.setdefault(int(t), []).append(int(l))
    consist = [max(np.bincount(v).max() / len(v), 0)
               for v in pairs.values() if len(v) >= 10]
    assert np.mean(consist) > 0.5


def test_checkpoint_roundtrip_and_rotation(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, async_save=False)
    tree = {"a": jnp.arange(12.0).reshape(3, 4),
            "b": {"c": jnp.ones((5,), jnp.int32), "d": None}}
    for s in (1, 2, 3):
        mgr.save(s, jax.tree.map(lambda x: x + s if x is not None else None,
                                 tree, is_leaf=lambda x: x is None))
    assert mgr.all_steps() == [2, 3]          # rotation kept last 2
    out = mgr.restore(3)
    np.testing.assert_allclose(np.asarray(out["a"]),
                               np.asarray(tree["a"]) + 3)
    assert out["b"]["d"] is None
    assert mgr.latest_step() == 3


def test_checkpoint_async_and_atomicity(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3, async_save=True)
    mgr.save(5, {"x": jnp.ones((4, 4))})
    mgr.wait()
    assert not any(n.endswith(".tmp") for n in os.listdir(tmp_path))
    out = mgr.restore()
    np.testing.assert_array_equal(np.asarray(out["x"]), np.ones((4, 4)))


def test_straggler_monitor_rebalances():
    from repro.core import load_balance as lb
    mon = StragglerMonitor(num_owners=4, window=5, threshold=1.2)
    for _ in range(5):
        mon.record(np.array([1.0, 1.0, 1.0, 3.0]))   # owner 3 is 3x slow
    assert mon.should_rebalance()
    shapes = {(64, 64): 12}
    cm = lb.analytic_cost_model(shapes)
    asn = mon.rebalance(shapes, cm)
    loads = asn.loads(cm)
    assert loads[3] < loads[:3].mean()    # degraded owner got less work


def test_viable_mesh_shape():
    assert viable_mesh_shape(256) == (16, 16)
    assert viable_mesh_shape(512, prefer_model=16) == (32, 16)
    assert viable_mesh_shape(252, prefer_model=16) == (18, 14)
    assert viable_mesh_shape(1) == (1, 1)


def test_viable_mesh_shape_no_survivors_raises():
    """Total device loss must abort planning, not divide by zero."""
    with pytest.raises(ValueError):
        viable_mesh_shape(0)
    with pytest.raises(ValueError):
        viable_mesh_shape(-4)


def test_straggler_monitor_memory_bounded():
    """A months-long run holds window x num_owners floats, not one per step."""
    mon = StragglerMonitor(num_owners=2, window=5, threshold=1.2)
    for i in range(50):
        mon.record(np.array([1.0, 1.0 + i]))
    assert len(mon._times) == 5
    # estimate reflects only the window (latest samples), not all history
    np.testing.assert_array_equal(mon._times[-1], [1.0, 50.0])
    np.testing.assert_array_equal(mon._times[0], [1.0, 46.0])
    mon.reset()
    assert len(mon._times) == 0
    assert not mon.should_rebalance()
    np.testing.assert_array_equal(mon.speed_estimate(), np.ones(2))


def test_step_timer_history_bounded():
    timer = StepTimer(max_history=8)
    for _ in range(30):
        with timer:
            pass
    assert len(timer.history) == 8
    assert timer.last == timer.history[-1]
    assert timer.recent(3) == list(timer.history)[-3:]
    assert len(timer.recent(100)) == 8      # clamped to available samples


@pytest.mark.parametrize("mode", ["owner", "gather", "adamw"])
def test_train_loop_loss_decreases(mode):
    cfg = configs.get("smollm-360m", reduced=True)
    plan = api.dedicate_params(
        jax.eval_shape(lambda k: __import__("repro.models", fromlist=["m"])
                       .model_fns(cfg).init(cfg, k), jax.random.PRNGKey(0)),
        num_owners=2, strategy="greedy")
    opt = api.Muon(plan, config=MuonConfig(
        mode=mode, learning_rate=0.02, adam_lr=2e-3))
    state = init_state(cfg, opt, jax.random.PRNGKey(0))
    step = make_train_step(cfg, opt, donate=False)
    dcfg = DataConfig(vocab=cfg.vocab, seq_len=32, global_batch=8)

    losses = []
    from repro.train.step import make_loss_fn
    loss_fn = jax.jit(make_loss_fn(cfg))
    for i in range(10):
        batch = batch_for_step(dcfg, i)
        losses.append(float(loss_fn(state.params, batch)))
        state = step(state, batch)
    assert np.isfinite(losses).all()
    assert losses[-1] < losses[0], (mode, losses)


def test_train_restart_bit_exact(tmp_path):
    """Checkpoint at step 3, restart, continue — states must match exactly."""
    cfg = configs.get("smollm-360m", reduced=True)
    from repro.models import model_fns
    shapes = jax.eval_shape(lambda k: model_fns(cfg).init(cfg, k),
                            jax.random.PRNGKey(0))
    plan = api.dedicate_params(shapes, num_owners=2, strategy="greedy")
    opt = api.Muon(plan, config=MuonConfig(mode="owner"))
    dcfg = DataConfig(vocab=cfg.vocab, seq_len=16, global_batch=4)
    step = make_train_step(cfg, opt, donate=False)

    state = init_state(cfg, opt, jax.random.PRNGKey(0))
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    for i in range(6):
        if i == 3:
            mgr.save(3, state._asdict())
        state = step(state, batch_for_step(dcfg, i))

    restored = mgr.restore(3)
    state2 = type(state)(**restored)
    for i in range(3, 6):
        state2 = step(state2, batch_for_step(dcfg, i))
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(state2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_grad_accumulation_matches_full_batch():
    cfg = configs.get("smollm-360m", reduced=True)
    plan = api.dedicate_params(
        jax.eval_shape(lambda k: __import__("repro.models", fromlist=["m"])
                       .model_fns(cfg).init(cfg, k), jax.random.PRNGKey(0)),
        num_owners=2, strategy="greedy")
    opt = api.Muon(plan, config=MuonConfig(mode="owner"))
    dcfg = DataConfig(vocab=cfg.vocab, seq_len=16, global_batch=8)
    batch = batch_for_step(dcfg, 0)

    s1 = init_state(cfg, opt, jax.random.PRNGKey(0))
    s2 = init_state(cfg, opt, jax.random.PRNGKey(0))
    full = make_train_step(cfg, opt, accum_steps=1, donate=False)(s1, batch)
    accum = make_train_step(cfg, opt, accum_steps=4, donate=False)(s2, batch)
    for a, b in zip(jax.tree.leaves(full.params),
                    jax.tree.leaves(accum.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=2e-5)