"""The layout layer in isolation: OwnerLayout pack/unpack round-trips,
owner-buffer allocation, and cross-plan row repacking — no optimizer
involved (the point of the layout/orthogonalizer/update-rule split)."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import api
from repro.core.owner_comms import (OwnerLayout, group_key_str, pack_group,
                                    repack_rows, unpack_group)


def _params(n_mats=6, shape=(16, 48), seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), n_mats)
    return {f"layer{i}": {"w": jax.random.normal(ks[i], shape)}
            for i in range(n_mats)}


def test_layout_pack_unpack_roundtrip():
    params = _params()
    plan = api.dedicate_params(params, num_owners=4, strategy="greedy")
    layout = OwnerLayout(plan)
    for key in layout.group_keys:
        g = plan.groups[key]
        leaves = {p: params[p.split("/")[0]]["w"] for p in g.leaf_paths}
        packed = layout.pack(key, leaves)
        assert packed.shape == layout.packed_shape(key)
        out = layout.unpack(key, packed)
        for p, v in out.items():
            np.testing.assert_array_equal(np.asarray(v),
                                          np.asarray(leaves[p]))


def test_layout_matches_module_functions():
    """OwnerLayout is a binding of the primitive functions, not a fork."""
    params = _params()
    plan = api.dedicate_params(params, num_owners=2, strategy="greedy")
    layout = OwnerLayout(plan)
    key = layout.group_keys[0]
    leaves = {p: params[p.split("/")[0]]["w"]
              for p in plan.groups[key].leaf_paths}
    np.testing.assert_array_equal(
        np.asarray(layout.pack(key, leaves)),
        np.asarray(pack_group(plan, key, leaves)))
    packed = pack_group(plan, key, leaves)
    a = layout.unpack(key, packed)
    b = unpack_group(plan, key, packed)
    for p in a:
        np.testing.assert_array_equal(np.asarray(a[p]), np.asarray(b[p]))


def test_zeros_buffers_and_trailing_override():
    params = _params()
    plan = api.dedicate_params(params, num_owners=4, strategy="greedy")
    layout = OwnerLayout(plan)
    key = layout.group_keys[0]
    g = plan.groups[key]
    mom = layout.zeros(key, jnp.float32)
    assert mom.shape == (g.packed_size,) + g.key
    v = layout.zeros(key, jnp.float32, trailing=(g.key[0],))
    assert v.shape == (g.packed_size, g.key[0])
    q = layout.zeros(key, jnp.float32, trailing=(g.key[0], g.key[0]))
    assert q.shape == (g.packed_size, g.key[0], g.key[0])


def test_repack_rows_preserves_logical_rows():
    """Unpack-under-old + repack-under-new keeps every logical row, for any
    buffer rank (momentum stacks and variant state alike)."""
    # one leaf with 6 stacked matrices -> a group with count 6
    params = {"w": jax.random.normal(jax.random.PRNGKey(0), (6, 8, 24))}
    plan4 = api.dedicate_params(params, num_owners=4, strategy="greedy")
    plan2 = api.dedicate_params(params, num_owners=2, strategy="greedy")
    g4, g2 = plan4.groups["w"], plan2.groups["w"]
    buf4 = pack_group(plan4, "w", {"w": params["w"]})
    assert buf4.shape[0] == g4.packed_size
    buf2 = repack_rows(g4, g2, buf4)
    back = repack_rows(g2, g4, buf2)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(buf4))
    # logical rows survive in order under the new plan
    got = np.take(np.asarray(buf2), g2.unpack_index, axis=0)
    want = np.take(np.asarray(buf4), g4.unpack_index, axis=0)
    np.testing.assert_array_equal(got, want)


def test_group_key_str_sanitizes():
    assert "/" not in group_key_str("blocks/0/wq")
    assert group_key_str((16, 64)) == "16x64"
