"""Property-based tests (hypothesis) on the paged KV allocator's
invariants: the BlockPool never double-frees, never leaks (free + live
always equals the pool size), and the logical→physical mapping across all
live block tables stays injective — no two tables, and no two entries of
one table, share a physical block (refcount-shared blocks excepted, and
the null block is never mapped).

Pure host-side accounting (no jax arrays), so these run in milliseconds
and can afford long random operation sequences.
"""

import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need the optional hypothesis dep "
    "(pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.serve.paged import (NULL_BLOCK, BlockPool, BlockTable,  # noqa: E402
                               PoolExhausted)

_SETTINGS = dict(max_examples=50, deadline=None)


def _check_invariants(pool, tables):
    # conservation: every block is exactly one of {free, live}
    assert pool.num_free + pool.num_live == pool.num_blocks
    # injectivity: no physical block mapped twice across live tables
    # (no table here shares, so each live block has exactly one owner)
    seen = set()
    for t in tables:
        for b in t.blocks:
            assert b != NULL_BLOCK
            assert 1 <= b <= pool.num_blocks
            assert b not in seen, f"block {b} mapped twice"
            seen.add(b)
    assert len(seen) == pool.num_live


@settings(**_SETTINGS)
@given(
    pool_size=st.integers(1, 32),
    ops=st.lists(st.tuples(st.sampled_from(["grow", "release", "new"]),
                           st.integers(0, 7), st.integers(1, 4)),
                 min_size=1, max_size=60),
)
def test_pool_table_invariants_under_random_ops(pool_size, ops):
    """Random grow/release/new sequences — with PoolExhausted and
    table-overflow errors absorbed, exactly as the slot manager absorbs
    them — keep conservation and injectivity intact."""
    pool = BlockPool(pool_size)
    max_blocks = max(pool_size // 2, 1)
    tables = [BlockTable(pool, max_blocks)]
    for op, idx, n in ops:
        t = tables[idx % len(tables)]
        if op == "grow":
            before = t.num_blocks
            try:
                t.grow(n)
            except PoolExhausted:
                # failed grow must not leak partial allocations beyond
                # what conservation accounts for
                assert t.num_blocks >= before
            except ValueError:
                assert t.num_blocks + n > t.max_blocks
        elif op == "release":
            t.release()
        else:
            tables.append(BlockTable(pool, max_blocks))
        _check_invariants(pool, tables)
    for t in tables:
        t.release()
    assert pool.num_free == pool.num_blocks


@settings(**_SETTINGS)
@given(pool_size=st.integers(1, 16), seq=st.data())
def test_no_double_free(pool_size, seq):
    """Freeing a block the pool does not consider live always raises —
    whether it was never allocated, already freed, or out of range."""
    pool = BlockPool(pool_size)
    held = [pool.alloc() for _ in range(
        seq.draw(st.integers(0, pool_size)))]
    freed = []
    while held:
        b = held.pop()
        pool.free(b)
        freed.append(b)
    for b in freed:
        with pytest.raises(ValueError, match="double free"):
            pool.free(b)
    with pytest.raises(ValueError):
        pool.free(NULL_BLOCK)
    with pytest.raises(ValueError):
        pool.free(pool_size + 1)
    assert pool.num_free == pool.num_blocks


@settings(**_SETTINGS)
@given(pool_size=st.integers(2, 16), extra=st.integers(1, 3))
def test_refcount_sharing_delays_recycle(pool_size, extra):
    """A share()d block survives its first free()s and returns to the
    free list only when the last reference drops."""
    pool = BlockPool(pool_size)
    b = pool.alloc()
    for _ in range(extra):
        pool.share(b)
    for _ in range(extra):
        pool.free(b)
        assert pool.num_free == pool_size - 1   # still live
    pool.free(b)
    assert pool.num_free == pool_size
    with pytest.raises(ValueError, match="double free"):
        pool.free(b)


def test_exhaustion_is_typed_and_recoverable():
    pool = BlockPool(2)
    a, b = pool.alloc(), pool.alloc()
    with pytest.raises(PoolExhausted):
        pool.alloc()
    pool.free(a)
    assert pool.alloc() == a                    # LIFO recycle
    with pytest.raises(ValueError, match="not live"):
        pool.share(NULL_BLOCK)
