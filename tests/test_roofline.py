"""Roofline harness: HLO walker trip-count correction vs analytic FLOPs."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.launch import hlo_walker
from repro.launch.roofline import collective_bytes
from repro.models import model_fns


def _walk_flops(nl):
    cfg = configs.get("smollm-360m", reduced=True, n_layers=nl, d_model=128,
                      n_heads=4, n_kv_heads=2, d_ff=256, vocab=512,
                      remat=False)
    m = model_fns(cfg)
    params = m.init(cfg, jax.random.PRNGKey(0))
    tok = jnp.zeros((2, 64), jnp.int32)
    c = jax.jit(lambda p, t: m.forward(cfg, p, t)).lower(params, tok).compile()
    return hlo_walker.analyze_text(c.as_text()), cfg, params


def test_walker_scales_with_layers():
    """cost_analysis is trip-count-blind; the walker must not be."""
    c2, _, _ = _walk_flops(2)
    c8, _, _ = _walk_flops(8)
    assert 3.0 < c8.flops / c2.flops < 4.5   # ~4x more layer flops + head


def test_walker_matches_analytic_forward_flops():
    costs, cfg, params = _walk_flops(8)
    n_params = cfg.param_count()
    tokens = 2 * 64
    analytic = 2.0 * n_params * tokens      # forward ≈ 2·N·T (+attention)
    assert 0.5 * analytic < costs.flops < 3.0 * analytic


def test_walker_finds_matmul_flops_exactly():
    a = jnp.zeros((128, 256))
    b = jnp.zeros((256, 64))
    c = jax.jit(lambda x, y: x @ y).lower(a, b).compile()
    costs = hlo_walker.analyze_text(c.as_text())
    assert costs.flops == 2 * 128 * 256 * 64


def test_walker_scan_trip_count():
    def f(x):
        def body(c, _):
            return c @ c, None
        out, _ = jax.lax.scan(body, x, None, length=7)
        return out
    c = jax.jit(f).lower(jnp.zeros((64, 64))).compile()
    costs = hlo_walker.analyze_text(c.as_text())
    assert costs.flops == pytest.approx(7 * 2 * 64**3, rel=0.01)


def test_collective_bytes_regex():
    hlo = """
ENTRY %main (p: f32[8,4]) -> f32[8,4] {
  %p = f32[8,4]{1,0} parameter(0)
  %ag = f32[16,4]{1,0} all-gather(f32[8,4]{1,0} %p), replica_groups={{0,1}}
  ROOT %ar = f32[8,4]{1,0} all-reduce(f32[8,4]{1,0} %p), to_apply=%add
}
"""
    out = collective_bytes(hlo)
    assert out["all-gather"] == 8 * 4 * 4
    assert out["all-reduce"] == 8 * 4 * 4
    assert out["total"] == 2 * 8 * 4 * 4
