"""Autotune cache pre-warming at optimizer init (kernels/autotune.py).

The paper's §3.3 workflow tunes once per (mode, shape, dtype) and dispatches
cached winners afterwards; here the optimizer pre-warms the persistent cache
for every kernel shape its dedication plan can launch, and the cached
winners must agree with the analytical roofline scorer re-run from scratch.
"""

import json

import jax
import jax.numpy as jnp
import pytest

from repro.core import api
from repro.core.muon import MuonConfig
from repro.kernels import autotune


@pytest.fixture(autouse=True)
def _isolated_cache(tmp_path, monkeypatch):
    """Point the persistent cache at a tmp file and reset the memory cache
    around each test (the module caches are process-global)."""
    path = str(tmp_path / "autotune.json")
    monkeypatch.setattr(autotune, "_DEFAULT_CACHE", path)
    autotune.clear_memory_cache()
    yield path
    autotune.clear_memory_cache()


def _params():
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    return {
        "wq": jax.random.normal(ks[0], (3, 64, 64)) * 0.02,
        "up": jax.random.normal(ks[1], (3, 64, 256)) * 0.02,
        "down": jax.random.normal(ks[2], (3, 256, 64)) * 0.02,
        "norm": jnp.ones((3, 64)),
    }


def test_plan_shapes_covers_all_kernel_modes():
    plan = api.dedicate_params(_params(), num_owners=2, strategy="greedy")
    shapes = autotune.plan_shapes(plan)
    gram_dims = {g.key[0] for g in plan.groups.values()}
    # one syrk per distinct (m, n), gram_poly + symmul per distinct m
    assert {(mode, m) for mode, m, _ in shapes if mode != "syrk"} == \
        {(mode, m) for m in gram_dims for mode in ("gram_poly", "symmul")}
    syrks = {(m, k) for mode, m, k in shapes if mode == "syrk"}
    assert syrks == {g.key for g in plan.groups.values()}


def test_prewarm_populates_persistent_cache(_isolated_cache):
    plan = api.dedicate_params(_params(), num_owners=2, strategy="greedy")
    n = autotune.prewarm_plan(plan, dtypes=("float32", "bfloat16"),
                              cache_path=_isolated_cache)
    shapes = autotune.plan_shapes(plan)
    assert n == 2 * len(shapes)
    with open(_isolated_cache) as f:
        cached = json.load(f)
    for dt in ("float32", "bfloat16"):
        for mode, m, k in shapes:
            assert f"{mode}:{m}x{k}:{dt}" in cached, (mode, m, k, dt)


def test_cached_winners_match_analytical_scorer(_isolated_cache):
    """Cross-check: every cached winner is the argmin of the analytical
    roofline score over the candidate block space, recomputed from scratch."""
    plan = api.dedicate_params(_params(), num_owners=2, strategy="greedy")
    autotune.prewarm_plan(plan, cache_path=_isolated_cache)
    with open(_isolated_cache) as f:
        cached = json.load(f)
    for mode, m, k in autotune.plan_shapes(plan):
        winner = tuple(cached[f"{mode}:{m}x{k}:float32"])
        best = min(autotune.candidate_blocks(m, k, 4),
                   key=lambda bk: autotune.analytical_score(*bk, m, k, 4))
        assert winner == best, (mode, m, k, winner, best)
        # and the public lookup path returns exactly the cached winner
        assert autotune.lookup(mode, m, k, "float32",
                               cache_path=_isolated_cache) == winner


def test_muon_init_prewarms(_isolated_cache):
    params = _params()
    plan = api.dedicate_params(params, num_owners=2, strategy="greedy")
    api.Muon(plan, config=MuonConfig(mode="owner"))
    with open(_isolated_cache) as f:
        cached = json.load(f)
    for mode, m, k in autotune.plan_shapes(plan):
        assert f"{mode}:{m}x{k}:float32" in cached


def test_reinit_over_warm_cache_never_retunes(_isolated_cache, monkeypatch):
    """Re-running init over an already-warm cache — ``Muon.replace()``,
    repeated ``Muon(...)`` on elastic restarts — must skip every cached
    (mode, m, k, dtype) entry: zero tune calls, while still reporting the
    full covered-entry count."""
    params = _params()
    plan = api.dedicate_params(params, num_owners=2, strategy="greedy")
    opt = api.Muon(plan, config=MuonConfig(mode="owner"))   # warms the cache

    calls = []
    real_tune = autotune.tune

    def counting_tune(*args, **kw):
        calls.append(args)
        return real_tune(*args, **kw)

    monkeypatch.setattr(autotune, "tune", counting_tune)
    n = autotune.prewarm_plan(plan)
    assert n == len(autotune.plan_shapes(plan))   # still reports coverage
    assert calls == []                            # but never re-tunes
    api.Muon(plan, config=MuonConfig(mode="owner"))
    opt.replace(pipeline="bucketed")
    opt.replace(variant="dion2")
    assert calls == []


def test_cached_entry_is_read_only(_isolated_cache):
    """``cached_entry`` reports misses as None without tuning or writing."""
    import os
    assert autotune.cached_entry("syrk", 64, 256, "float32",
                                 cache_path=_isolated_cache) is None
    assert not os.path.exists(_isolated_cache)
    autotune.tune("syrk", 64, 256, "float32", cache_path=_isolated_cache)
    hit = autotune.cached_entry("syrk", 64, 256, "float32",
                                cache_path=_isolated_cache)
    assert hit == autotune.lookup("syrk", 64, 256, "float32",
                                  cache_path=_isolated_cache)


def test_prewarm_opt_out_and_elementwise_skip(_isolated_cache):
    import os
    params = _params()
    plan = api.dedicate_params(params, num_owners=2, strategy="greedy")
    api.Muon(plan, config=MuonConfig(mode="owner", autotune_prewarm=False))
    assert not os.path.exists(_isolated_cache)
    # the adamw variant never launches Gram kernels — nothing to warm
    api.Muon(plan, config=MuonConfig(variant="adamw"))
    assert not os.path.exists(_isolated_cache)
