"""Serving-tier tests: slot-parity (bit-identical logits through insert /
evict / recycle), chunked prefill, queue/slot units, and the structural
continuous-vs-oneshot decode-step advantage.

The load-bearing guarantee: a request served through the continuous-batching
scheduler — prefilled packed with strangers, written into a recycled slot
row, decoded in a batch whose other rows sit at different depths — produces
the SAME logits, bit for bit, as the same prompt run solo through
``prefill_fn`` + scalar-pos ``decode_fn``.  That holds because (a) on this
backend row i of a batched decode equals the batch-1 result bitwise, and
(b) slot insertion copies full cache rows and masking never reads beyond a
slot's own position.  float32 caches everywhere (bf16 would round the
reference too — parity must not hide behind tolerance).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import model_fns
from repro.serve import (Request, RequestQueue, Scheduler, ServeConfig,
                         SlotManager, run_oneshot)
from repro.train import serve as serve_fns

PARITY_ARCHS = ["smollm-360m", "xlstm-350m", "seamless-m4t-large-v2"]


def _build(arch):
    cfg = configs.get(arch, reduced=True)
    m = model_fns(cfg)
    params = jax.jit(lambda k: m.init(cfg, k))(jax.random.PRNGKey(0))
    return cfg, m, params


@pytest.fixture(scope="module", params=PARITY_ARCHS)
def served(request):
    """Run a recycling-heavy workload (7 requests through 3 slots, packed
    prefill, mixed budgets) with logits recording on."""
    cfg, m, params = _build(request.param)
    enc_kw = dict(frontend_dim=cfg.frontend_dim, prompt_lens=(8,)) \
        if cfg.encdec else dict(prompt_lens=(4, 8))
    queue = RequestQueue.synthetic(7, cfg.vocab, new_tokens=(2, 6),
                                   seed=3, **enc_kw)
    reqs = {r.rid: r for r in queue._pending}   # kept for solo replay
    scfg = ServeConfig(num_slots=3, max_len=32, prefill_pack=2,
                       cache_dtype=jnp.float32, record_logits=True,
                       enc_len=8 if cfg.encdec else None)
    sched = Scheduler(cfg, params, scfg)
    metrics = sched.run(queue)
    return cfg, params, scfg, metrics, reqs


def test_slot_parity_bitwise(served):
    """Every served request's logit stream is bit-identical to the same
    prompt decoded solo (batch=1, scalar positions, fresh cache)."""
    cfg, params, scfg, metrics, reqs = served
    assert len(metrics.requests) == 7
    if cfg.encdec:
        prefill = jax.jit(lambda p, t, f: serve_fns.prefill_fn(
            cfg, p, t, scfg.max_len, cache_dtype=jnp.float32, frames=f))
    else:
        prefill = jax.jit(lambda p, t: serve_fns.prefill_fn(
            cfg, p, t, scfg.max_len, cache_dtype=jnp.float32))
    decode = jax.jit(lambda p, t, c, pos: serve_fns.decode_fn(
        cfg, p, t, c, pos))
    prefix = cfg.frontend_len \
        if cfg.frontend is not None and not cfg.encdec else 0

    for rec in metrics.requests.values():
        req = reqs[rec.rid]
        toks = jnp.asarray(req.tokens)[None]
        args = (jnp.asarray(req.frames)[None],) if cfg.encdec else ()
        logits, cache = prefill(params, toks, *args)
        ref = [np.asarray(logits[0])]
        tok = int(np.argmax(ref[0]))
        assert tok == rec.tokens[0], rec.rid
        for i in range(1, rec.generated):
            logits, cache = decode(
                params, jnp.asarray([tok], jnp.int32), cache,
                jnp.asarray(req.prompt_len + prefix + i - 1, jnp.int32))
            ref.append(np.asarray(logits[0]))
            tok = int(np.argmax(ref[-1]))
            assert tok == rec.tokens[i], (rec.rid, i)
        assert len(ref) == len(rec.logits), rec.rid
        for i, (a, b) in enumerate(zip(ref, rec.logits)):
            assert np.array_equal(a, b), \
                f"rid {rec.rid} token {i}: served logits != solo logits"


def test_served_requests_complete(served):
    cfg, params, scfg, metrics, _ = served
    for rec in metrics.requests.values():
        assert rec.generated == rec.requested
        assert rec.t_first is not None and rec.t_done is not None
        assert rec.t_done >= rec.t_first >= rec.arrival


def test_metrics_summary_sane(served):
    cfg, params, scfg, metrics, _ = served
    s = metrics.summary()
    assert s["requests"] == 7
    assert s["tokens"] == sum(r.generated for r in metrics.requests.values())
    assert 0.0 < s["slot_occupancy"] <= 1.0
    assert s["tokens_per_sec"] > 0
    assert s["ttft_ms_p90"] >= s["ttft_ms_median"] >= 0
    assert s["decode_steps"] == len(metrics.decode_step_s)


def test_chunked_prefill_matches_full():
    """prefill_chunk over an existing cache == one-shot prefill.  Attention
    caches are bitwise (chunking only splits the write schedule); the xLSTM
    associative scan re-associates, so it gets a tolerance."""
    for arch, exact in [("smollm-360m", True), ("xlstm-350m", False)]:
        cfg, m, params = _build(arch)
        toks = jax.random.randint(jax.random.PRNGKey(7), (1, 12),
                                  0, cfg.vocab)
        max_len = 24
        if cfg.family == "ssm":
            full, _ = m.prefill(cfg, params, toks, max_len)
        else:
            full, _ = m.prefill(cfg, params, toks, max_len,
                                cache_dtype=jnp.float32)
        cache = m.init_cache(cfg, 1, max_len, jnp.float32)
        for off in range(0, 12, 4):
            logits, cache = serve_fns.prefill_chunk_fn(
                cfg, params, toks[:, off:off + 4], cache,
                jnp.asarray(off, jnp.int32))
        if exact:
            assert np.array_equal(np.asarray(logits), np.asarray(full)), arch
        else:
            np.testing.assert_allclose(np.asarray(logits), np.asarray(full),
                                       rtol=1e-5, atol=1e-5, err_msg=arch)


def test_continuous_beats_oneshot_decode_steps():
    """Structural (count-based, deterministic): on a bimodal-budget
    workload the slot scheduler needs strictly fewer decode steps than
    lockstep rounds at the same batch size."""
    cfg, m, params = _build("smollm-360m")

    def wl():
        return RequestQueue.synthetic(8, cfg.vocab, prompt_lens=(4,),
                                      budgets=(2, 2, 2, 12), seed=5)
    sched = Scheduler(cfg, params, ServeConfig(num_slots=4, max_len=24,
                                               cache_dtype=jnp.float32))
    cont = sched.run(wl()).summary()
    q = wl()
    q.poll(0.0)
    reqs = [q.pop_group(1)[0] for _ in range(len(q))]
    base = run_oneshot(cfg, params, reqs, batch=4, max_len=24,
                       cache_dtype=jnp.float32).summary()
    assert cont["tokens"] == base["tokens"]
    assert cont["decode_steps"] < base["decode_steps"], \
        (cont["decode_steps"], base["decode_steps"])


# ------------------------------------------------------------ queue units

def _req(rid, n, budget=4, arrival=0.0):
    return Request(rid=rid, tokens=np.arange(n, dtype=np.int32),
                   max_new_tokens=budget, arrival=arrival)


def test_queue_packs_equal_lengths_only():
    q = RequestQueue([_req(0, 4), _req(1, 4), _req(2, 8), _req(3, 4)])
    q.poll(0.0)
    g = q.pop_group(3)
    assert [r.rid for r in g] == [0, 1, 3]       # len-8 skipped, kept
    assert [r.rid for r in q.pop_group(3)] == [2]
    assert q.drained


def test_queue_chunked_prompts_go_alone():
    q = RequestQueue([_req(0, 32), _req(1, 32)])
    q.poll(0.0)
    assert [r.rid for r in q.pop_group(4, chunk_len=16)] == [0]
    assert [r.rid for r in q.pop_group(4, chunk_len=16)] == [1]


def test_queue_arrivals_gate_readiness():
    q = RequestQueue([_req(0, 4, arrival=0.5), _req(1, 4, arrival=0.1)])
    assert q.num_ready == 0 and not q.drained
    assert q.next_arrival() == pytest.approx(0.1)
    assert q.poll(0.2) == 1
    assert [r.rid for r in q.pop_group(4)] == [1]
    assert q.poll(1.0) == 1
    assert [r.rid for r in q.pop_group(4)] == [0]


def test_synthetic_deterministic():
    a = RequestQueue.synthetic(5, 100, rate=10.0, seed=9)
    b = RequestQueue.synthetic(5, 100, rate=10.0, seed=9)
    for x, y in zip(a._pending, b._pending):
        assert np.array_equal(x.tokens, y.tokens)
        assert x.arrival == y.arrival and x.max_new_tokens == y.max_new_tokens


# ------------------------------------------------------------- slot units

def test_slot_lifecycle_and_errors():
    cfg, m, params = _build("smollm-360m")
    sm = SlotManager(cfg, 2, max_len=16, cache_dtype=jnp.float32)
    assert sm.num_free == 2 and sm.num_active == 0
    _, rcache = m.prefill(cfg, params,
                          jnp.zeros((1, 4), jnp.int32), 16,
                          cache_dtype=jnp.float32)
    i = sm.insert(_req(0, 4), rcache, 0, first_token=1, pos=4)
    assert sm.num_active == 1 and int(sm.pos[i]) == 4 and int(sm.tok[i]) == 1
    sm.advance(i, 7)
    assert int(sm.pos[i]) == 5 and sm.slots[i].generated == 2
    j = sm.insert(_req(1, 4), rcache, 0, first_token=2, pos=15)
    assert sm.num_free == 0
    assert sm.out_of_cache(j) is False
    sm.advance(j, 3)
    assert sm.out_of_cache(j) is True
    with pytest.raises(RuntimeError):
        sm.insert(_req(2, 4), rcache, 0, first_token=0, pos=4)
    s = sm.evict(i)
    assert s.request.rid == 0 and sm.num_free == 1
    with pytest.raises(ValueError):
        sm.evict(i)
    with pytest.raises(ValueError):
        sm.insert(_req(3, 4), rcache, 0, first_token=0, pos=16)
    # recycled row is claimed again without zeroing
    k = sm.insert(_req(4, 4), rcache, 0, first_token=5, pos=4)
    assert k == i


def test_encdec_slots_require_enc_len():
    cfg, _, _ = _build("seamless-m4t-large-v2")
    with pytest.raises(ValueError, match="enc_len"):
        SlotManager(cfg, 2, max_len=16)


# ---------------------------------------------------------- paged serving

from repro.serve import PagedSlotManager  # noqa: E402
from repro.serve.paged import NULL_BLOCK  # noqa: E402

# the three cache families of DESIGN.md §12: grouped-KV (smollm), MLA
# latent (deepseek), pure-recurrent state (xlstm)
PAGED_PARITY_ARCHS = ["smollm-360m", "deepseek-v3-671b", "xlstm-350m"]


@pytest.fixture(scope="module", params=PAGED_PARITY_ARCHS)
def served_paged(request):
    """The slot-parity workload on the paged allocator, with the
    ``preempt_every`` drill forcing preempt→requeue→resume cycles."""
    cfg, m, params = _build(request.param)
    queue = RequestQueue.synthetic(7, cfg.vocab, prompt_lens=(4, 8),
                                   new_tokens=(2, 6), seed=3)
    reqs = {r.rid: r for r in queue._pending}
    scfg = ServeConfig(num_slots=3, max_len=32, prefill_pack=2,
                       cache_dtype=jnp.float32, record_logits=True,
                       kv="paged", block_size=8, preempt_every=4)
    sched = Scheduler(cfg, params, scfg)
    metrics = sched.run(queue)
    return cfg, params, sched.max_len, metrics, reqs


def test_paged_parity_bitwise(served_paged):
    """Paged serving — block-scattered prefill, gather-indirected decode,
    at least one preempt→resume cycle — is bit-identical to solo
    contiguous decode, for KV, MLA and recurrent cache families."""
    cfg, params, max_len, metrics, reqs = served_paged
    assert metrics.preemptions >= 1     # the drill actually fired
    assert len(metrics.requests) == 7
    prefill = jax.jit(lambda p, t: serve_fns.prefill_fn(
        cfg, p, t, max_len, cache_dtype=jnp.float32))
    decode = jax.jit(lambda p, t, c, pos: serve_fns.decode_fn(
        cfg, p, t, c, pos))
    for rec in metrics.requests.values():
        req = reqs[rec.rid]
        logits, cache = prefill(params, jnp.asarray(req.tokens)[None])
        ref = [np.asarray(logits[0])]
        tok = int(np.argmax(ref[0]))
        assert tok == rec.tokens[0], rec.rid
        for i in range(1, rec.generated):
            logits, cache = decode(
                params, jnp.asarray([tok], jnp.int32), cache,
                jnp.asarray(req.prompt_len + i - 1, jnp.int32))
            ref.append(np.asarray(logits[0]))
            tok = int(np.argmax(ref[-1]))
            assert tok == rec.tokens[i], (rec.rid, i)
        assert len(ref) == len(rec.logits), rec.rid
        for i, (a, b) in enumerate(zip(ref, rec.logits)):
            assert np.array_equal(a, b), \
                f"rid {rec.rid} token {i}: paged logits != solo logits"


def test_paged_requests_complete(served_paged):
    cfg, params, max_len, metrics, _ = served_paged
    for rec in metrics.requests.values():
        assert rec.generated == rec.requested
        assert not rec.rejected
    s = metrics.summary()
    assert s["preemptions"] >= 1
    if cfg.family != "ssm":
        assert s["pool_blocks"] > 0
        assert 0.0 <= s["pool_occupancy"] <= 1.0


def test_paged_pool_pressure_preempts():
    """An under-provisioned pool (1.5 slots' worth of blocks for 4 slots)
    forces organic preemption — no drill — and every request still
    completes with its full budget."""
    cfg, m, params = _build("smollm-360m")
    queue = RequestQueue.synthetic(8, cfg.vocab, prompt_lens=(4, 8),
                                   new_tokens=(8, 20), seed=5)
    scfg = ServeConfig(num_slots=4, max_len=32, prefill_pack=2,
                       cache_dtype=jnp.float32, kv="paged",
                       block_size=8, pool_blocks=6)
    metrics = Scheduler(cfg, params, scfg).run(queue)
    assert metrics.preemptions >= 1
    for rec in metrics.requests.values():
        assert rec.generated == rec.requested


@pytest.mark.parametrize("kv", ["contiguous", "paged"])
def test_overlength_rejected_gracefully(kv):
    """A prompt that alone fills the cache is rejected at admission —
    recorded done with the ``rejected`` marker — instead of raising out
    of SlotManager.insert; later fitting requests are unaffected."""
    cfg, m, params = _build("smollm-360m")
    q = RequestQueue()
    q.push(_req(0, 40, budget=4))       # 40 >= max_len 32: over-length
    q.push(_req(1, 8, budget=4))
    scfg = ServeConfig(num_slots=2, max_len=32, cache_dtype=jnp.float32,
                       kv=kv, block_size=8)
    metrics = Scheduler(cfg, params, scfg).run(q)
    r0, r1 = metrics.requests[0], metrics.requests[1]
    assert r0.rejected and r0.generated == 0
    assert r0.t_first is None and r0.t_done is not None
    assert not r1.rejected and r1.generated == 4
    assert metrics.summary()["rejected"] == 1


def test_paged_beats_contiguous_concurrency_equal_memory():
    """The headline: at equal cache bytes (12 blocks × 8 tokens), the paged
    tier sustains strictly more concurrent requests than the contiguous
    tier on a bimodal long+short workload, because short requests only
    reserve the blocks they touch."""
    cfg, m, params = _build("smollm-360m")

    def wl():
        return RequestQueue.synthetic(12, cfg.vocab, prompt_lens=(4,),
                                      budgets=(4, 4, 4, 24), seed=11)
    cont = Scheduler(cfg, params, ServeConfig(
        num_slots=3, max_len=32, cache_dtype=jnp.float32)).run(wl())
    paged = Scheduler(cfg, params, ServeConfig(
        num_slots=6, max_len=32, cache_dtype=jnp.float32, kv="paged",
        block_size=8, pool_blocks=12)).run(wl())
    cs, ps = cont.summary(), paged.summary()
    assert ps["tokens"] == cs["tokens"]
    assert ps["concurrent_mean"] > cs["concurrent_mean"], (cs, ps)
    assert ps["decode_steps"] < cs["decode_steps"], (cs, ps)
    for rec in paged.requests.values():
        assert rec.generated == rec.requested


def test_paged_slot_units():
    """PagedSlotManager lifecycle: block accounting across insert /
    advance / evict, table release, null-block invariant."""
    cfg, m, params = _build("smollm-360m")
    sm = PagedSlotManager(cfg, 2, max_len=16, block_size=4,
                          cache_dtype=jnp.float32)
    assert sm.max_len == 16 and sm.blocks_per_slot == 4
    assert sm.pool.num_blocks == 8 and sm.pool.num_free == 8
    _, rcache = m.prefill(cfg, params, jnp.zeros((1, 4), jnp.int32), 16,
                          cache_dtype=jnp.float32)
    i = sm.insert(_req(0, 4), rcache, 0, first_token=1, pos=4)
    assert sm.tables[i].num_blocks == 2          # covers positions 0..4
    assert sm.pool.num_free == 6
    assert NULL_BLOCK not in sm.tables[i].blocks
    bt = sm.block_tables()
    assert bt.shape == (2, 4)
    assert (bt[1 - i] == NULL_BLOCK).all()       # free slot: all-null row
    reserved, used, pool_blocks, used_blocks = sm.pool_stats()
    assert (reserved, used, pool_blocks, used_blocks) == (8, 4, 8, 2)
    sm.evict(i)
    assert sm.pool.num_free == 8 and sm.tables[i] is None
    # exhaustion: two full-length tables drain the pool
    a = sm.insert(_req(1, 4), rcache, 0, first_token=1, pos=15)
    b = sm.insert(_req(2, 4), rcache, 0, first_token=1, pos=11)
    assert sm.pool.num_free == 1
    sm.pos[b] = 15                               # next write needs a block
    preempted = sm.prepare_decode()
    assert [p.request.rid for p in preempted] == []   # 1 free block: fits
    assert sm.pool.num_free == 0
    assert sm.tables[a].num_blocks == 4 and sm.tables[b].num_blocks == 4


def test_paged_prepare_decode_preempts_youngest():
    cfg, m, params = _build("smollm-360m")
    sm = PagedSlotManager(cfg, 2, max_len=16, block_size=4,
                          pool_blocks=5, cache_dtype=jnp.float32)
    _, rcache = m.prefill(cfg, params, jnp.zeros((1, 4), jnp.int32), 16,
                          cache_dtype=jnp.float32)
    a = sm.insert(_req(0, 4), rcache, 0, first_token=1, pos=7)   # 2 blocks
    b = sm.insert(_req(1, 4), rcache, 0, first_token=1, pos=7)   # 2 blocks
    sm.advance(a, 3)                             # pos 8: needs a 3rd block
    sm.advance(b, 3)
    preempted = sm.prepare_decode()
    assert [p.request.rid for p in preempted] == [1]   # youngest evicted
    assert sm.slots[b] is None and sm.num_active == 1
    assert preempted[0].generated == 2 and preempted[0].tokens == [1, 3]
    assert sm.tables[a].num_blocks == 3


def test_paged_encdec_unsupported():
    cfg, _, _ = _build("seamless-m4t-large-v2")
    with pytest.raises(NotImplementedError):
        PagedSlotManager(cfg, 2, max_len=16, enc_len=8)
