"""Data pipeline determinism + checkpointable-cursor contract tests:

* restart contract — restoring the ``state()`` cursor replays batches
  k, k+1, ... byte-identically to an uninterrupted stream;
* ``Pipeline.state()`` rides the checkpoint tree through CheckpointManager
  and repositions a fresh pipeline;
* prefetch worker shuts down cleanly (``close()`` while the thread is
  blocked mid-``put`` must not hang or leak the thread);
* ``_batch_at`` is a pure function of (config, step) — identical bytes in a
  separate interpreter process.
"""

import hashlib
import json
import pathlib
import subprocess
import sys
import time

import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.data.pipeline import DataConfig, Pipeline, _batch_at, batch_for_step

CFG = DataConfig(vocab=64, seq_len=16, global_batch=4, seed=7)


def _digest(batch: dict) -> str:
    h = hashlib.sha256()
    for k in sorted(batch):
        h.update(k.encode())
        h.update(np.ascontiguousarray(np.asarray(batch[k])).tobytes())
    return h.hexdigest()


def test_restart_replays_stream_exactly():
    """Consume k batches, snapshot the cursor, keep going; a fresh pipeline
    restored from the snapshot yields byte-identical batches k, k+1, ..."""
    pipe = Pipeline(CFG)
    try:
        for _ in range(3):
            next(pipe)
        snap = pipe.state()
        want = [_digest(next(pipe)) for _ in range(5)]
    finally:
        pipe.close()

    fresh = Pipeline(CFG)
    try:
        next(fresh)                      # arbitrary position before restore
        fresh.restore(snap)
        got = [_digest(next(fresh)) for _ in range(5)]
    finally:
        fresh.close()
    assert got == want


def test_state_is_cursor_of_next_batch():
    pipe = Pipeline(CFG)
    try:
        assert int(np.asarray(pipe.state()["data_step"])) == 0
        for i in range(4):
            batch = next(pipe)
            np.testing.assert_array_equal(
                np.asarray(batch["tokens"]),
                np.asarray(batch_for_step(CFG, i)["tokens"]))
        assert int(np.asarray(pipe.state()["data_step"])) == 4
    finally:
        pipe.close()


def test_state_roundtrips_through_checkpoint_manager(tmp_path):
    """The cursor rides the checkpoint tree: save state(), restore into a
    fresh pipeline, stream continues from the saved position."""
    pipe = Pipeline(CFG)
    try:
        for _ in range(5):
            next(pipe)
        mgr = CheckpointManager(str(tmp_path), async_save=False)
        mgr.save(5, {"data": pipe.state()})
    finally:
        pipe.close()

    fresh = Pipeline(CFG)
    try:
        fresh.restore(mgr.restore()["data"])
        np.testing.assert_array_equal(
            np.asarray(next(fresh)["tokens"]),
            np.asarray(batch_for_step(CFG, 5)["tokens"]))
    finally:
        fresh.close()


def test_close_mid_put_shuts_worker_down():
    """With nothing consuming, the worker blocks on a full queue; close()
    must unblock it and join within the timeout (no leaked thread)."""
    pipe = Pipeline(CFG, prefetch=1)
    deadline = time.monotonic() + 5.0
    while not pipe._q.full() and time.monotonic() < deadline:
        time.sleep(0.01)                 # let the worker fill the queue
    assert pipe._q.full()
    pipe.close()
    assert not pipe._thread.is_alive()


def test_seek_discards_prefetched_batches():
    pipe = Pipeline(CFG, prefetch=2)
    try:
        next(pipe)                       # worker now prefetching steps 1, 2
        pipe.seek(10)
        np.testing.assert_array_equal(
            np.asarray(next(pipe)["tokens"]),
            np.asarray(batch_for_step(CFG, 10)["tokens"]))
        assert int(np.asarray(pipe.state()["data_step"])) == 11
    finally:
        pipe.close()


def test_batch_at_pure_across_processes():
    """_batch_at must not depend on interpreter state (hash seeds, import
    order): a fresh process produces identical bytes for the same cursor."""
    steps = [0, 3, 11]
    want = {s: _digest(_batch_at(CFG, s)) for s in steps}
    prog = (
        "import hashlib, json, sys\n"
        "import numpy as np\n"
        "from repro.data.pipeline import DataConfig, _batch_at\n"
        f"cfg = DataConfig(vocab={CFG.vocab}, seq_len={CFG.seq_len}, "
        f"global_batch={CFG.global_batch}, seed={CFG.seed})\n"
        "def digest(b):\n"
        "    h = hashlib.sha256()\n"
        "    for k in sorted(b):\n"
        "        h.update(k.encode())\n"
        "        h.update(np.ascontiguousarray(np.asarray(b[k])).tobytes())\n"
        "    return h.hexdigest()\n"
        f"print(json.dumps({{s: digest(_batch_at(cfg, s)) for s in {steps}}}))\n"
    )
    out = subprocess.run([sys.executable, "-c", prog], capture_output=True,
                         text=True, timeout=120, env={"PYTHONPATH": "src",
                                                      "PATH": "/usr/bin:/bin",
                                                      "HOME": "/tmp"},
                         cwd=str(pathlib.Path(__file__).parents[1]))
    assert out.returncode == 0, out.stderr
    got = {int(k): v for k, v in json.loads(out.stdout).items()}
    assert got == want
