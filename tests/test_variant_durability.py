"""Every registered variant's optimizer state must survive the durability
surface: ``state_dict`` → checkpoint save/restore → ``load_state_dict``
bitwise, and elastic owner-count resharding (D=4 → 2 → 4) preserving every
unpacked momentum and variant-state row bit-exactly.  Parametrized over the
whole registry so a future variant cannot ship without durable state."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager
from repro.core import api
from repro.core.api import reshard_owner_state
from repro.core.gram_ns import GramNSConfig
from repro.core.muon import MuonConfig


def _params():
    return {"w": jax.random.normal(jax.random.PRNGKey(0), (6, 8, 24)) * 0.02,
            "bias": jnp.zeros((24,))}


def _grads(params, seed):
    return jax.tree.map(
        lambda x: jax.random.normal(jax.random.PRNGKey(seed),
                                    x.shape) * 0.1, params)


def _opt(variant, num_owners):
    params = _params()
    plan = api.dedicate_params(params, num_owners=num_owners,
                               strategy="greedy")
    cfg = MuonConfig(variant=variant, ns=GramNSConfig(num_steps=5))
    return params, plan, api.Muon(plan, config=cfg)


def _advance(opt, params, n=2):
    st = opt.init(params)
    for t in range(n):
        _, st = opt.update(_grads(params, t), st, params)
    return st


@pytest.mark.parametrize("variant", sorted(api.VARIANTS))
def test_state_dict_checkpoint_roundtrip_every_variant(tmp_path, variant):
    params, _, opt = _opt(variant, 4)
    st = _advance(opt, params)
    d = opt.state_dict(st)
    if api.get_variant(variant).stateful:
        assert d["variant_state"] is not None
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    mgr.save(2, d, block=True)
    restored = opt.load_state_dict(mgr.restore(2))
    assert jax.tree_util.tree_structure(restored) == \
        jax.tree_util.tree_structure(st)
    for a, b in zip(jax.tree.leaves(st), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # and training continues bit-identically from the restored state
    u1, _ = opt.update(_grads(params, 9), st, params)
    u2, _ = opt.update(_grads(params, 9), restored, params)
    for a, b in zip(jax.tree.leaves(u1), jax.tree.leaves(u2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("variant", sorted(api.VARIANTS))
def test_reshard_preserves_rows_every_variant(variant):
    params, plan4, opt4 = _opt(variant, 4)
    _, plan2, _ = _opt(variant, 2)
    st = _advance(opt4, params)
    st2 = reshard_owner_state(st, plan4, plan2)
    back = reshard_owner_state(st2, plan2, plan4)

    def rows(plan, buf):
        return np.take(np.asarray(buf, np.float32),
                       plan.groups["w"].unpack_index, axis=0)

    for skey, buf in st.momentum.items():
        np.testing.assert_array_equal(rows(plan4, buf),
                                      rows(plan2, st2.momentum[skey]))
        np.testing.assert_array_equal(np.asarray(buf),
                                      np.asarray(back.momentum[skey]))
    if st.variant_state is not None:
        for field, bufs in st.variant_state.items():
            for skey, buf in (bufs or {}).items():
                np.testing.assert_array_equal(
                    rows(plan4, buf),
                    rows(plan2, st2.variant_state[field][skey]))
                np.testing.assert_array_equal(
                    rows(plan4, buf),
                    rows(plan4, back.variant_state[field][skey]))
