"""Optimizer semantics: owner-centric DMuon == Muon-AG == per-matrix reference.

The paper's central semantic claim (§3.5): the owner receives the same
averaged full-matrix gradient a synchronous Muon reference would use, applies
the same momentum and NS update, and publishes the same parameter.  Modes
must agree to NS-rounding tolerance.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import api
from repro.core.gram_ns import GramNSConfig
from repro.core.muon import MuonConfig, _scale_factor
from repro.core.newton_schulz import newton_schulz


def _tree(seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 6)
    return {
        "blocks": {
            "wq": jax.random.normal(ks[0], (3, 32, 32)) * 0.02,
            "wo": jax.random.normal(ks[1], (3, 32, 32)) * 0.02,
            "up": jax.random.normal(ks[2], (3, 32, 128)) * 0.02,
            "down": jax.random.normal(ks[3], (3, 128, 32)) * 0.02,
            "norm_scale": jnp.ones((3, 32)),
        },
        "embed_table": jax.random.normal(ks[4], (100, 32)) * 0.02,
    }


def _grads(seed=1):
    return jax.tree.map(
        lambda x: jax.random.normal(jax.random.PRNGKey(seed + x.size % 97),
                                    x.shape) * 0.1, _tree())


def _mk(mode, **kw):
    params = _tree()
    plan = api.dedicate_params(params, num_owners=4, strategy="greedy")
    cfg = MuonConfig(mode=mode, learning_rate=0.1, momentum=0.9,
                     nesterov=True, ns=GramNSConfig(num_steps=5), **kw)
    opt = api.Muon(plan, config=cfg)
    return params, plan, opt


@pytest.mark.parametrize("steps", [1, 3])
def test_owner_equals_gather(steps):
    params_o, _, opt_o = _mk("owner")
    params_g, _, opt_g = _mk("gather")
    so, sg = opt_o.init(params_o), opt_g.init(params_g)
    for t in range(steps):
        g = _grads(seed=t)
        uo, so = opt_o.update(g, so, params_o)
        ug, sg = opt_g.update(g, sg, params_g)
        params_o = jax.tree.map(lambda p, u: p + u, params_o, uo)
        params_g = jax.tree.map(lambda p, u: p + u, params_g, ug)
    for po, pg in zip(jax.tree.leaves(params_o), jax.tree.leaves(params_g)):
        np.testing.assert_allclose(np.asarray(po), np.asarray(pg),
                                   rtol=5e-4, atol=5e-5)


def test_matches_manual_reference():
    """Single step vs a hand-written Muon update per matrix."""
    params, plan, opt = _mk("owner")
    state = opt.init(params)
    grads = _grads()
    updates, _ = opt.update(grads, state, params)

    g = grads["blocks"]["wq"][1]
    mom = g  # zero momentum buffer: buf = 0.9*0 + g
    eff = g + 0.9 * mom  # nesterov
    o = newton_schulz(eff, num_steps=5)
    want = -0.1 * o * _scale_factor(32, 32, "match_rms_adam")
    np.testing.assert_allclose(np.asarray(updates["blocks"]["wq"][1]),
                               np.asarray(want), rtol=5e-3, atol=5e-4)


def test_momentum_accumulates():
    params, plan, opt = _mk("owner")
    state = opt.init(params)
    g = _grads()
    _, s1 = opt.update(g, state, params)
    _, s2 = opt.update(g, s1, params)
    key = next(iter(s2.momentum))
    m1 = np.asarray(s1.momentum[key], dtype=np.float32)
    m2 = np.asarray(s2.momentum[key], dtype=np.float32)
    np.testing.assert_allclose(m2, 1.9 * m1, rtol=1e-5)  # 0.9*m + g = 1.9g


def test_non_matrix_params_take_adamw():
    params, plan, opt = _mk("owner")
    state = opt.init(params)
    grads = _grads()
    updates, _ = opt.update(grads, state, params)
    g = np.asarray(grads["embed_table"], dtype=np.float32)
    # AdamW step 0: mu=(1-b1)g, nu=(1-b2)g², bias-corrected => update = -lr*sign-ish
    want = -3e-4 * ((1 - 0.9) * g / (1 - 0.9)) / (
        np.sqrt((1 - 0.95) * g * g / (1 - 0.95)) + 1e-8)
    np.testing.assert_allclose(np.asarray(updates["embed_table"]), want,
                               rtol=1e-4, atol=1e-7)


def test_weight_decay_applied():
    params, _, _ = _mk("owner")
    _, plan2, opt_wd = _mk("owner", weight_decay=0.5)
    state = opt_wd.init(params)
    g0 = jax.tree.map(jnp.zeros_like, _grads())
    updates, _ = opt_wd.update(g0, state, params)
    # zero grads: NS(0) ~ 0 so update ≈ -lr * wd * p
    w = np.asarray(params["blocks"]["wq"])
    got = np.asarray(updates["blocks"]["wq"])
    np.testing.assert_allclose(got, -0.1 * 0.5 * w, atol=2e-3)


def test_adamw_mode_covers_everything():
    params, plan, opt = _mk("adamw")
    state = opt.init(params)
    updates, s2 = opt.update(_grads(), state, params)
    assert jax.tree_util.tree_structure(updates) == \
        jax.tree_util.tree_structure(params)
    assert s2.step == 1


def test_state_dict_roundtrip():
    params, plan, opt = _mk("owner")
    state = opt.init(params)
    _, state = opt.update(_grads(), state, params)
    d = opt.state_dict(state)
    state2 = opt.load_state_dict(d)
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(state2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_compressed_grad_transpose_error_feedback():
    params, plan, opt = _mk("owner", compress_grads=True)
    state = opt.init(params)
    assert state.error_feedback is not None
    g = _grads()
    u1, s1 = opt.update(g, state, params)
    # residual is nonzero (bf16 quantization) but bounded by quantization step
    ef = np.asarray(s1.error_feedback["blocks/wq"])
    assert 0 < np.abs(ef).max() < 1e-2
    # and the update stays close to the uncompressed one
    params2, _, opt2 = _mk("owner")
    u2, _ = opt2.update(g, opt2.init(params2), params2)
    np.testing.assert_allclose(np.asarray(u1["blocks"]["wq"]),
                               np.asarray(u2["blocks"]["wq"]),
                               rtol=0.1, atol=5e-3)


def test_bucket_fusion_matches_per_group():
    """Fusing the Gram iteration across same-m groups is semantics-neutral
    (paper §3.3 batched execution)."""
    from repro.core.gram_ns import GramNSConfig
    params = _tree()
    grads = _grads()
    plan = api.dedicate_params(params, num_owners=4, strategy="greedy")
    assert any(len(v) > 1 for v in plan.buckets.values())  # fusable bucket
    opt_a = api.Muon(plan, config=MuonConfig(mode="owner"))
    opt_b = api.Muon(plan, config=MuonConfig(
        mode="owner", ns=GramNSConfig(bucket_fusion=True)))
    ua, _ = opt_a.update(grads, opt_a.init(params), params)
    ub, _ = opt_b.update(grads, opt_b.init(params), params)
    for a, b in zip(jax.tree.leaves(ua), jax.tree.leaves(ub)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-6)
