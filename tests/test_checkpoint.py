"""Checkpoint manager contract tests (fault-tolerance substrate):

* save/restore round-trip of the FULL train tree — params + owner-sharded
  ``MuonState`` including per-variant state — exactly as the resilient loop
  writes it (train tree + data cursor + owner-count meta);
* ``keep=N`` rotation;
* async ``save(..., block=False)`` + ``wait()`` ordering (one in-flight save
  at a time, later saves see earlier ones committed);
* restore-latest after a partial write (a crash mid-save leaves a ``.tmp``
  directory that must be invisible to ``latest_step``/``restore``).
"""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.checkpoint.manager import CheckpointManager
from repro.core import api
from repro.core.muon import MuonConfig
from repro.data.pipeline import DataConfig, batch_for_step
from repro.models import model_fns
from repro.train.step import init_state, make_train_step
from repro.train.train_state import TrainState


def _train_tree(variant: str, steps: int = 2):
    """A real train tree after ``steps`` updates (momentum + variant state
    populated), in the composite layout the resilient loop checkpoints."""
    cfg = configs.get("smollm-360m", reduced=True, n_layers=2)
    shapes = jax.eval_shape(lambda k: model_fns(cfg).init(cfg, k),
                            jax.random.PRNGKey(0))
    plan = api.dedicate_params(shapes, num_owners=2, strategy="greedy")
    opt = api.Muon(plan, config=MuonConfig(variant=variant))
    state = init_state(cfg, opt, jax.random.PRNGKey(0))
    step = make_train_step(cfg, opt, donate=False)
    dcfg = DataConfig(vocab=cfg.vocab, seq_len=16, global_batch=4)
    for i in range(steps):
        state = step(state, batch_for_step(dcfg, i))
    return {"train": state._asdict(),
            "data": {"data_step": np.asarray(steps, np.int64)},
            "meta": {"num_owners": np.asarray(2, np.int64)}}


def _assert_trees_equal(a, b):
    la = jax.tree_util.tree_leaves_with_path(a)
    lb = jax.tree_util.tree_leaves_with_path(b)
    assert jax.tree_util.tree_structure(a) == jax.tree_util.tree_structure(b)
    for (kp, x), (_, y) in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y), err_msg=str(kp))
        assert np.asarray(x).dtype == np.asarray(y).dtype, kp


@pytest.mark.parametrize("variant", ["muon", "normuon", "muonbp"])
def test_full_train_tree_roundtrip(tmp_path, variant):
    """The composite checkpoint tree — params, owner-sharded MuonState incl.
    variant_state, data cursor, owner meta — round-trips bit-exactly."""
    tree = _train_tree(variant)
    if variant == "muon":
        assert tree["train"]["opt_state"].variant_state is None
    else:
        assert tree["train"]["opt_state"].variant_state is not None
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    mgr.save(2, tree)
    out = mgr.restore()
    _assert_trees_equal(tree, out)
    # the restored opt_state is a real MuonState (treedef round-trip), so the
    # resumed run can hand it straight back to the optimizer
    restored = TrainState(**out["train"])
    assert type(restored.opt_state).__name__ == "MuonState"
    assert int(np.asarray(out["data"]["data_step"])) == 2
    assert int(np.asarray(out["meta"]["num_owners"])) == 2


def test_keep3_rotation(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3, async_save=False)
    for s in range(1, 6):
        mgr.save(s, {"x": jnp.full((3,), float(s))})
    assert mgr.all_steps() == [3, 4, 5]
    assert mgr.latest_step() == 5
    np.testing.assert_array_equal(np.asarray(mgr.restore()["x"]),
                                  np.full((3,), 5.0))


def test_async_save_then_wait_ordering(tmp_path):
    """Consecutive non-blocking saves serialize (one in-flight at a time):
    after wait(), every step is committed and the latest restores to the
    latest payload — no torn or reordered commits."""
    mgr = CheckpointManager(str(tmp_path), keep=4, async_save=True)
    for s in (1, 2, 3):
        mgr.save(s, {"x": jnp.full((4, 4), float(s)), "step": jnp.asarray(s)})
    mgr.wait()
    assert mgr.all_steps() == [1, 2, 3]
    assert not any(n.endswith(".tmp") for n in os.listdir(tmp_path))
    for s in (1, 2, 3):
        out = mgr.restore(s)
        np.testing.assert_array_equal(np.asarray(out["x"]),
                                      np.full((4, 4), float(s)))
    np.testing.assert_array_equal(np.asarray(mgr.restore()["step"]), 3)


def test_async_save_snapshot_is_synchronous(tmp_path):
    """``save`` snapshots to host memory before returning: mutating (donating)
    the live buffers after an async save must not corrupt the checkpoint."""
    mgr = CheckpointManager(str(tmp_path), async_save=True)
    x = np.arange(8.0)
    tree = {"x": x}
    mgr.save(1, tree)
    x += 100.0                      # training step overwrites the buffer
    mgr.wait()
    np.testing.assert_array_equal(np.asarray(mgr.restore()["x"]),
                                  np.arange(8.0))


def test_restore_latest_after_partial_write(tmp_path):
    """A crash mid-save leaves ``step_N.tmp``; it must not shadow the last
    committed step, and a fresh manager over the directory must restore it."""
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    mgr.save(7, {"x": jnp.ones((2, 2)) * 7})
    # simulate dying mid-write of step 9: tmp dir with a manifest-less shard
    tmp = os.path.join(str(tmp_path), "step_000000009.tmp")
    os.makedirs(tmp)
    np.savez(os.path.join(tmp, "leaf_dead.shard0.npz"),
             data=np.zeros((2, 2)), index=np.asarray([[0, 2], [0, 2]]))
    mgr2 = CheckpointManager(str(tmp_path), async_save=False)
    assert mgr2.all_steps() == [7]
    assert mgr2.latest_step() == 7
    np.testing.assert_array_equal(np.asarray(mgr2.restore()["x"]),
                                  np.ones((2, 2)) * 7)


def test_restore_missing_raises(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    with pytest.raises(FileNotFoundError):
        mgr.restore()
