"""dedicate_params: classification, grouping, packed-layout round trips."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import api
from repro.core.muon import pack_group, unpack_group


def _params():
    k = jax.random.PRNGKey(0)
    ks = jax.random.split(k, 8)
    return {
        "embed": {"table": jax.random.normal(ks[0], (1000, 64))},
        "layers": {
            "attn_q": jax.random.normal(ks[1], (4, 64, 64)),    # stacked L=4
            "attn_o": jax.random.normal(ks[2], (4, 64, 64)),
            "mlp_up": jax.random.normal(ks[3], (4, 64, 256)),
            "mlp_down": jax.random.normal(ks[4], (4, 256, 64)),  # transposed
            "norm_scale": jnp.ones((4, 64)),
            "mlp_bias": jnp.zeros((4, 256)),
            "experts_up": jax.random.normal(ks[5], (2, 4, 64, 128)),  # L=2,E=4
        },
        "lm_head": jax.random.normal(ks[6], (64, 1000)),
        "final_norm": jnp.ones((64,)),
    }


@pytest.fixture(scope="module")
def plan():
    return api.dedicate_params(_params(), num_owners=4, strategy="greedy")


def test_classification(plan):
    assert "layers/attn_q" in plan.leaves
    assert "layers/experts_up" in plan.leaves
    assert "embed/table" not in plan.leaves          # excluded by name
    assert "lm_head" not in plan.leaves
    assert any("norm_scale" in p for p in plan.adamw_paths)
    assert any("mlp_bias" in p for p in plan.adamw_paths)


def test_grouping_and_transpose(plan):
    # execution groups are per leaf; shape census aggregates across leaves
    assert plan.groups["layers/attn_q"].key == (64, 64)
    assert plan.groups["layers/attn_q"].count == 4
    assert plan.groups["layers/mlp_down"].key == (64, 256)
    assert plan.leaves["layers/mlp_down"].transpose is True
    assert plan.leaves["layers/mlp_up"].transpose is False
    # census (load-balancer view) merges same-shape leaves
    assert plan.assignment.owner_of[(64, 64)].shape == (8,)   # q + o
    assert plan.assignment.owner_of[(64, 256)].shape == (8,)  # up + down
    # MoE experts: 2*4 = 8 matrices of (64, 128) in one leaf
    assert plan.groups["layers/experts_up"].count == 8


def test_owner_major_pack_layout(plan):
    for key, g in plan.groups.items():
        assert g.packed_size == plan.num_owners * g.capacity
        # every member appears exactly once; pads are -1
        members = g.pack_index[g.pack_index >= 0]
        assert sorted(members.tolist()) == list(range(g.count))
        # owner of position p is p // capacity, matching owner_of
        for w in range(g.count):
            pos = g.unpack_index[w]
            assert g.pack_index[pos] == w
            assert pos // g.capacity == g.owner_of[w]


def test_pack_unpack_roundtrip(plan):
    params = _params()
    for key, g in plan.groups.items():
        leaf_vals = {p: params_at(params, p) for p in g.leaf_paths}
        packed = pack_group(plan, key, leaf_vals)
        m, n = g.key
        assert packed.shape == (g.packed_size, m, n)
        out = unpack_group(plan, key, packed)
        for p in g.leaf_paths:
            np.testing.assert_array_equal(np.asarray(out[p]),
                                          np.asarray(leaf_vals[p]))


def params_at(tree, path):
    node = tree
    for part in path.split("/"):
        node = node[part]
    return node


def test_gram_buckets(plan):
    # all groups here have gram dim 64 -> single bucket fusing all 5 leaves
    assert set(plan.buckets) == {64}
    assert len(plan.buckets[64]) == 5


def test_plan_with_shape_structs_only():
    """Dry-run path: planning must work on ShapeDtypeStructs, no arrays."""
    structs = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                           _params())
    plan = api.dedicate_params(structs, num_owners=8, strategy="round_robin")
    assert plan.stats["num_matrices"] == 24
    assert plan.stats["padding_waste"] >= 0


def test_stats(plan):
    assert plan.stats["num_matrices"] == 24
    assert plan.stats["num_groups"] == 5          # per-leaf groups
    # embed/table, norm_scale, mlp_bias, lm_head, final_norm
    assert plan.stats["num_adamw_leaves"] == 5
