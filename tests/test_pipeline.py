"""The bucketed optimizer pipeline (core/pipeline.py, docs/DESIGN.md §6).

Bit-exactness is the contract: the bucketed schedule is the fused owner
update *reordered*, so on one device every variant must produce bitwise
identical updates and state — including the accumulation-overlapped entry
(per-microbatch staging inside the scan), which rides on packing being a
permutation + zero-pad.  Plus the elasticity of in-flight staged state.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import api
from repro.core.gram_ns import GramNSConfig
from repro.core.muon import MuonConfig
from repro.core.owner_comms import group_key_str
from repro.core.pipeline import BucketPipeline, reshard_staged

VARIANTS = ["muon", "normuon", "muonbp", "dion2", "adamuon", "adamw"]


def _tree(seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 6)
    return {
        "blocks": {
            "wq": jax.random.normal(ks[0], (3, 32, 32)) * 0.02,
            "wk": jax.random.normal(ks[1], (3, 32, 16)) * 0.02,
            "up": jax.random.normal(ks[2], (3, 32, 128)) * 0.02,
            "down": jax.random.normal(ks[3], (3, 128, 32)) * 0.02,
            "norm_scale": jnp.ones((3, 32)),
        },
        "embed_table": jax.random.normal(ks[4], (100, 32)) * 0.02,
    }


def _grads(seed=1):
    return jax.tree.map(
        lambda x: jax.random.normal(jax.random.PRNGKey(seed + x.size % 97),
                                    x.shape) * 0.1, _tree())


def _mk(variant, pipeline, **kw):
    params = _tree()
    plan = api.dedicate_params(params, num_owners=4, strategy="greedy")
    kw.setdefault("ns", GramNSConfig(num_steps=5))
    cfg = MuonConfig(variant=variant, pipeline=pipeline, learning_rate=0.1,
                     momentum=0.9, **kw)
    return params, plan, api.Muon(plan, config=cfg)


def _run(opt, params, n=3):
    state = opt.init(params)
    for t in range(n):
        u, state = opt.update(_grads(seed=t), state, params)
        params = jax.tree.map(lambda p, d: p + d, params, u)
    return params, state


def _assert_trees_equal(a, b, msg=""):
    fa = jax.tree_util.tree_leaves_with_path(a)
    fb = jax.tree_util.tree_leaves_with_path(b)
    assert len(fa) == len(fb), (msg, len(fa), len(fb))
    for (kp, x), (_, y) in zip(fa, fb):
        np.testing.assert_array_equal(
            np.asarray(x), np.asarray(y),
            err_msg=f"{msg}:{jax.tree_util.keystr(kp)}")


# ----------------------------------------------------- schedule structure

def test_plan_is_multi_bucket():
    # the fixture must actually exercise the pipeline: >= 2 Gram buckets
    _, plan, _ = _mk("muon", "bucketed")
    assert len(plan.buckets) >= 2, plan.buckets


def test_schedule_orders_buckets_largest_first():
    _, plan, opt = _mk("muon", "bucketed")
    pipe = BucketPipeline(plan, opt.config, spec=opt.variant)
    ms = [m for m, _ in pipe.schedule]
    assert ms == sorted(ms, reverse=True)
    assert sum(len(keys) for _, keys in pipe.schedule) == len(plan.groups)


def test_bucketed_rejects_gather_mode():
    params = _tree()
    plan = api.dedicate_params(params, num_owners=4, strategy="greedy")
    with pytest.raises(ValueError, match="pipeline"):
        opt = api.Muon(plan, config=MuonConfig(mode="gather",
                                               pipeline="bucketed"))
        opt.update(_grads(), opt.init(params), params)


def test_unknown_pipeline_rejected():
    params = _tree()
    plan = api.dedicate_params(params, num_owners=4, strategy="greedy")
    with pytest.raises(ValueError, match="pipeline"):
        opt = api.Muon(plan, config=MuonConfig(pipeline="wavefront"))
        opt.update(_grads(), opt.init(params), params)


# ------------------------------------------------- bit-exactness (fused ==)

@pytest.mark.parametrize("variant", VARIANTS)
def test_bucketed_bit_exact_with_fused(variant):
    params_f, state_f = _run(_mk(variant, "fused")[2], _tree())
    params_b, state_b = _run(_mk(variant, "bucketed")[2], _tree())
    _assert_trees_equal(params_f, params_b, f"{variant}:params")
    _assert_trees_equal(state_f.momentum, state_b.momentum,
                        f"{variant}:momentum")
    _assert_trees_equal(state_f.variant_state, state_b.variant_state,
                        f"{variant}:variant_state")
    _assert_trees_equal(state_f.adamw, state_b.adamw, f"{variant}:adamw")


@pytest.mark.parametrize("variant", VARIANTS)
def test_bucketed_bit_exact_with_bucket_fusion(variant):
    # ns.bucket_fusion fuses the iterate phase within a bucket — in both
    # schedules the fusion unit IS the bucket, so still bit-exact
    kw = {"ns": GramNSConfig(num_steps=5, bucket_fusion=True)}
    params_f, _ = _run(_mk(variant, "fused", **kw)[2], _tree())
    params_b, _ = _run(_mk(variant, "bucketed", **kw)[2], _tree())
    _assert_trees_equal(params_f, params_b, variant)


def test_bucketed_bit_exact_with_compress_grads():
    # compression's error feedback lives in the training layout and is
    # applied before stage_in — identical in both schedules
    kw = {"compress_grads": True}
    params_f, state_f = _run(_mk("muon", "fused", **kw)[2], _tree())
    params_b, state_b = _run(_mk("muon", "bucketed", **kw)[2], _tree())
    _assert_trees_equal(params_f, params_b, "params")
    _assert_trees_equal(state_f.error_feedback, state_b.error_feedback, "ef")


# ---------------------------------------- accumulation-overlapped schedule

@pytest.mark.parametrize("variant", VARIANTS)
def test_prestaged_accum_bit_exact(variant):
    """stage_in inside the scan + update_staged == accumulate + update.

    Packing is a permutation + zero-pad, so summing packed per-microbatch
    gradients, scaling by 1/accum and casting to pack_dtype commutes with
    packing the averaged gradient — for every registry variant.
    """
    from repro import configs
    from repro.data.pipeline import DataConfig, batch_for_step
    from repro.models import model_fns
    from repro.train.step import init_state, make_train_step

    cfg = configs.get("smollm-360m", reduced=True, n_layers=2, d_model=64,
                      n_heads=4, n_kv_heads=2, d_ff=176, vocab=256,
                      remat=False)
    shapes = jax.eval_shape(lambda k: model_fns(cfg).init(cfg, k),
                            jax.random.PRNGKey(0))
    plan = api.dedicate_params(shapes, num_owners=2, strategy="greedy")
    assert len(plan.buckets) >= 2     # GQA kv heads give a second bucket
    dcfg = DataConfig(vocab=cfg.vocab, seq_len=32, global_batch=4)
    batch = batch_for_step(dcfg, 0)

    outs = {}
    for prestage in (False, True):
        opt = api.Muon(plan, config=MuonConfig(
            mode="owner", variant=variant, pipeline="bucketed",
            ns=GramNSConfig(num_steps=3)))
        state = init_state(cfg, opt, jax.random.PRNGKey(0))
        step = make_train_step(cfg, opt, donate=False, accum_steps=2,
                               prestage=prestage)
        for _ in range(2):
            state = step(state, batch)
        outs[prestage] = state
    _assert_trees_equal(outs[False].params, outs[True].params,
                        f"{variant}:params")
    np.testing.assert_array_equal(np.asarray(outs[False].loss_ema),
                                  np.asarray(outs[True].loss_ema))
    _assert_trees_equal(outs[False].opt_state.momentum,
                        outs[True].opt_state.momentum, f"{variant}:momentum")
    _assert_trees_equal(outs[False].opt_state.variant_state,
                        outs[True].opt_state.variant_state,
                        f"{variant}:vstate")


def test_prestage_refused_with_compress_grads():
    from repro.core.muon import muon_update_staged
    params = _tree()
    plan = api.dedicate_params(params, num_owners=2, strategy="greedy")
    cfg = MuonConfig(mode="owner", pipeline="bucketed", compress_grads=True)
    with pytest.raises(ValueError, match="compress_grads"):
        muon_update_staged(plan, {}, {}, None, params, cfg)


# ------------------------------------------------- elastic in-flight state

def test_staged_state_elastic_reshard():
    """A preemption mid-accumulation: owner-major staged gradient sums are
    repacked to a new owner count, the interrupted step finishes there, and
    the result matches the uninterrupted run bit-for-bit."""
    params = _tree()
    g1, g2 = _grads(seed=11), _grads(seed=12)

    def staged_sum(plan, opt):
        pipe = BucketPipeline(plan, opt.config, spec=opt.variant)
        from repro.core.muon import _matrix_and_rest
        out = None
        for g in (g1, g2):
            gm, _, _ = _matrix_and_rest(plan, g)
            st = pipe.stage_in_all(gm, dtype=jnp.float32)
            out = st if out is None else {k: out[k] + st[k] for k in out}
        return {k: v * 0.5 for k, v in out.items()}

    def finish(plan, opt, staged):
        from repro.core.muon import _matrix_and_rest
        _, gr1, _ = _matrix_and_rest(plan, g1)
        _, gr2, _ = _matrix_and_rest(plan, g2)
        rest = {p: (gr1[p] + gr2[p]) * 0.5 for p in gr1}
        return opt.update_staged(staged, rest, opt.init(params), params)

    def mk(n):
        plan = api.dedicate_params(params, num_owners=n, strategy="greedy")
        return plan, api.Muon(plan, config=MuonConfig(
            mode="owner", pipeline="bucketed", learning_rate=0.1,
            momentum=0.9, ns=GramNSConfig(num_steps=5)))

    plan4, opt4 = mk(4)
    plan2, opt2 = mk(2)

    # uninterrupted at 2 owners
    u_ref, _ = finish(plan2, opt2, staged_sum(plan2, opt2))
    # interrupted at 4 owners mid-accumulation, resumed at 2
    staged4 = staged_sum(plan4, opt4)
    staged2 = reshard_staged(staged4, plan4, plan2)
    u_el, _ = finish(plan2, opt2, staged2)
    _assert_trees_equal(u_ref, u_el, "elastic")


def test_reshard_staged_roundtrip():
    params = _tree()
    plan4 = api.dedicate_params(params, num_owners=4, strategy="greedy")
    plan2 = api.dedicate_params(params, num_owners=2, strategy="greedy")
    opt = api.Muon(plan4, config=MuonConfig(mode="owner",
                                            pipeline="bucketed"))
    pipe = BucketPipeline(plan4, opt.config, spec=opt.variant)
    from repro.core.muon import _matrix_and_rest
    gm, _, _ = _matrix_and_rest(plan4, _grads())
    staged = pipe.stage_in_all(gm, dtype=jnp.float32)
    back = reshard_staged(reshard_staged(staged, plan4, plan2),
                          plan2, plan4)
    for key, grp in plan4.groups.items():
        skey = group_key_str(key)
        rows = grp.pack_index.shape[0] if hasattr(grp, "pack_index") else None
        np.testing.assert_array_equal(
            np.asarray(staged[skey]), np.asarray(back[skey]),
            err_msg=f"{skey} rows={rows}")


# ------------------------------------------------------- config surface

def test_replace_returns_new_opt():
    _, _, opt = _mk("muon", "fused")
    opt_b = opt.replace(pipeline="bucketed")
    assert opt.config.pipeline == "fused"
    assert opt_b.config.pipeline == "bucketed"
    assert opt_b.plan is opt.plan


def test_train_step_pipeline_override():
    from repro import configs
    from repro.models import model_fns
    from repro.train.step import make_train_step

    cfg = configs.get("smollm-360m", reduced=True, n_layers=2, d_model=64,
                      n_heads=4, n_kv_heads=2, d_ff=176, vocab=256,
                      remat=False)
    shapes = jax.eval_shape(lambda k: model_fns(cfg).init(cfg, k),
                            jax.random.PRNGKey(0))
    plan = api.dedicate_params(shapes, num_owners=1, strategy="greedy")
    opt = api.Muon(plan, config=MuonConfig(mode="owner"))
    make_train_step(cfg, opt, donate=False, pipeline="bucketed")
    assert opt.config.pipeline == "fused"   # caller's opt untouched


def test_pipeline_validation_in_resolve():
    from repro.core.muon import _resolve
    with pytest.raises(ValueError, match="pipeline"):
        _resolve(dataclasses.replace(MuonConfig(), pipeline="nope"))
