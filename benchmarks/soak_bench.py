"""Resilience soak benchmark: fault-injected drill through the supervised loop.

    PYTHONPATH=src python benchmarks/soak_bench.py --steps 24 --json

Drives ``repro.runtime.resilient.ResilientLoop`` (the production training
supervisor) through a reduced adversity drill — straggler slowdown, owner
kill + re-add, preemption + checkpoint restore — and reports the operational
metrics the resilience story is judged on:

    soak/drill       measured per-step wall time across the whole drill, plus
                     ``recovery_ms`` (median owner-loss/preemption recovery
                     latency) and ``rebalance_ms`` (median online re-plan +
                     state-migration latency) — the soak-suite record shape
                     benchmarks/check_regression.py validates;
    soak/recovery    one derived row per recovery event (kill/readd/preempt);
    soak/rebalance   derived re-plan row with the makespan drop.

Wall-clock numbers are for THIS host (XLA:CPU); the drill itself is the same
script tests/test_resilience.py runs at full length with bit-continuity
assertions.
"""

from __future__ import annotations

import argparse
import os
import sys
import tempfile

if __name__ == "__main__" and __package__ is None:  # direct execution
    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))

from benchmarks.common import record, record_to_csv, write_bench_json

# soak-suite extra fields on measured rows (validated by check_regression)
SOAK_FIELDS = ("recovery_ms", "rebalance_ms")


def _drill_spec(steps: int) -> str:
    """The reduced drill, scaled to ``steps`` (>= 12 for every event to
    land): early slowdown (rebalance), kill + re-add mid-run, preemption
    near the end restoring the latest committed checkpoint."""
    half = steps // 2
    return (f"slow@2:r3x4.0; kill@{half}:r1; readd@{half + 2}; "
            f"preempt@{steps - 2}")


def _median_ms(latencies_s) -> float:
    if not latencies_s:
        return 0.0
    s = sorted(latencies_s)
    mid = len(s) // 2
    med = s[mid] if len(s) % 2 else 0.5 * (s[mid - 1] + s[mid])
    return med * 1e3


def run_records(arch: str = "smollm-360m", steps: int = 24,
                owners: int = 4, seed: int = 0) -> list:
    from repro import configs
    from repro.core.muon import MuonConfig
    from repro.data.pipeline import DataConfig
    from repro.runtime.faults import FaultPlan
    from repro.runtime.resilient import ResilientConfig, ResilientLoop

    if steps < 12:
        raise ValueError(f"drill needs >= 12 steps (got {steps})")
    cfg = configs.get(arch, reduced=True, n_layers=2)
    dcfg = DataConfig(vocab=cfg.vocab, seq_len=16, global_batch=4)
    rcfg = ResilientConfig(steps=steps, ckpt_every=max(4, steps // 4),
                           window=3, cooldown=2, threshold=1.3, seed=seed)
    drill = _drill_spec(steps)

    with tempfile.TemporaryDirectory(prefix="soak_ckpt_") as ckpt_dir:
        loop = ResilientLoop(cfg, dcfg, muon=MuonConfig(), run=rcfg,
                             num_owners=owners, ckpt_dir=ckpt_dir,
                             faults=FaultPlan.parse(drill))
        report = loop.run()

    recovery_ms = _median_ms([r["latency_s"] for r in report.recoveries])
    rebalance_ms = _median_ms([r["latency_s"] for r in report.rebalances])

    rec = record("soak/drill", config=arch, mode="drill",
                 variant=loop.muon_cfg.variant,
                 samples_s=report.step_times)
    rec["recovery_ms"] = recovery_ms
    rec["rebalance_ms"] = rebalance_ms
    rec["derived"] = (f"steps={report.steps} executed={report.executed_steps} "
                      f"recoveries={len(report.recoveries)} "
                      f"rebalances={len(report.rebalances)} "
                      f"drill='{drill}'")
    records = [rec]

    for r in report.recoveries:
        extra = (f"resumed_step={r['resumed_step']}"
                 if r["kind"] == "preempt" else
                 f"owners {r['owners'][0]}->{r['owners'][1]}")
        records.append(record(
            "soak/recovery", config=arch, mode=r["kind"],
            value=r["latency_s"] * 1e3, unit="ms",
            derived=f"step={r['step']} {extra}"))
    for r in report.rebalances:
        records.append(record(
            "soak/rebalance", config=arch, mode="replan",
            value=r["latency_s"] * 1e3, unit="ms",
            derived=(f"step={r['step']} makespan "
                     f"{r['makespan_before_s']:.2e}s -> "
                     f"{r['makespan_after_s']:.2e}s")))
    return records


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--steps", type=int, default=24,
                    help="drill length in training steps (>= 12)")
    ap.add_argument("--owners", type=int, default=4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--json", nargs="?", const=".", default=None,
                    metavar="DIR", help="write BENCH_soak.json to DIR "
                                        "(default: repo root)")
    args = ap.parse_args()

    records = run_records(arch=args.arch, steps=args.steps,
                          owners=args.owners, seed=args.seed)
    print("name,us_per_call,derived")
    for rec in records:
        print(record_to_csv(rec), flush=True)
    if args.json is not None:
        path = os.path.join(args.json, "BENCH_soak.json")
        write_bench_json(path, "soak", records)
        print(f"# wrote {path}", file=sys.stderr)


if __name__ == "__main__":
    main()
