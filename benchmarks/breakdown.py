"""Table 2 analogue: per-component breakdown of DMuon's optimizer-step
speedup, by disabling each component in isolation:

  symmetric Gram kernel — Gram-space symmetric products vs full-GEMM Gram
                          (FLOP-exact model + measured Gram-vs-standard time)
  owner + load balance  — one owner per matrix (makespan) vs replicated NS
  batching + autotune   — batched stacks vs per-matrix launches (measured)
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import csv_row, time_fn
from repro.core import load_balance
from repro.core.gram_ns import GramNSConfig, gram_newton_schulz, gram_ns_flops
from repro.core.newton_schulz import newton_schulz

CENSUS = {(256, 1024): 32, (256, 256): 64, (128, 512): 96}
RANKS = 16


def _variant_rows(variant: str) -> list[str]:
    """Orthogonalizer-phase cost of a registered variant on one owner stack:
    the refresh step (full NS) vs the steady-state step (MuonBP's cached
    reuse; identical to refresh for stateless variants).  Quantifies the
    amortization each backend buys over the plain Gram path."""
    from repro.core import api
    from repro.core.muon import MuonConfig
    from repro.core.orthogonalize import make_orthogonalizer
    from repro.core.owner_comms import OwnerLayout, group_key_str

    spec = api.get_variant(variant)
    if spec.elementwise:
        return []
    x = jax.random.normal(jax.random.PRNGKey(2), (16, 128, 512)) * 0.02
    plan = api.dedicate_params({"w": x}, num_owners=1, strategy="greedy")
    mcfg = MuonConfig(variant=variant)
    layout = OwnerLayout(plan)
    ortho = make_orthogonalizer(spec.orthogonalizer, mcfg)
    state = ortho.init_state(layout, mcfg)
    stacks = {group_key_str("w"): x}

    fn = jax.jit(lambda sts, step, st: ortho(
        sts, step=step, state=st, layout=layout, cfg=mcfg))
    rows = []
    t_refresh = time_fn(fn, stacks, jnp.zeros((), jnp.int32), state)
    rows.append(csv_row(f"table2/variant/{variant}/ortho_refresh",
                        t_refresh * 1e6))
    # steady state: advance past the refresh boundary (step % period != 0)
    _, state1 = fn(stacks, jnp.zeros((), jnp.int32), state)
    t_steady = time_fn(fn, stacks, jnp.ones((), jnp.int32), state1)
    rows.append(csv_row(f"table2/variant/{variant}/ortho_steady",
                        t_steady * 1e6,
                        derived=f"refresh/steady={t_refresh/t_steady:.2f}x"))
    return rows


def run(variant: str = "muon") -> list[str]:
    rows = []
    cfg = GramNSConfig(num_steps=5)

    # ---- symmetric-kernel share (FLOP-exact; kernels halve every product)
    full = sym = std = 0.0
    for (m, n), c in CENSUS.items():
        f = gram_ns_flops(m, n, 5, batch=c)
        full += f["gram_full_gemm"]
        sym += f["gram_symmetric_kernel"]
        std += f["standard_ns"]
    rows.append(csv_row("table2/symmetric_kernel_flop_saving_pct",
                        (1 - sym / full) * 1e6, derived="pct_x1e4"))

    # ---- owner + LB: replicated cost vs balanced makespan
    cm = load_balance.analytic_cost_model(CENSUS)
    asn = load_balance.solve_greedy(CENSUS, cm, RANKS)
    replicated = sum(cm.per_matrix(s) * n for s, n in CENSUS.items())
    rows.append(csv_row("table2/owner_lb_speedup",
                        replicated / asn.makespan(cm) * 100,
                        derived="ratio_x100"))
    r0 = load_balance.rank0(CENSUS, RANKS)
    rows.append(csv_row("table2/rank0_ablation_slowdown",
                        r0.makespan(cm) / asn.makespan(cm) * 100,
                        derived="ratio_x100"))

    # ---- batching: measured batched stack vs per-matrix loop
    m, n, b = 128, 512, 16
    x = jax.random.normal(jax.random.PRNGKey(0), (b, m, n))
    fn_b = jax.jit(lambda v: gram_newton_schulz(v, cfg, assume_short_fat=True))
    t_batched = time_fn(fn_b, x)
    fn_1 = jax.jit(lambda v: gram_newton_schulz(v, cfg, assume_short_fat=True))
    x1 = x[:1]
    t_single = time_fn(fn_1, x1)
    rows.append(csv_row("table2/batching_speedup",
                        (t_single * b) / t_batched * 100,
                        derived="ratio_x100"))

    # ---- gram vs standard NS (measured, fat matrices where gram wins)
    xf = jax.random.normal(jax.random.PRNGKey(1), (8, 256, 2048))
    t_gram = time_fn(jax.jit(
        lambda v: gram_newton_schulz(v, cfg, assume_short_fat=True)), xf)
    t_std = time_fn(jax.jit(
        lambda v: newton_schulz(v, num_steps=5)), xf)
    rows.append(csv_row("table2/gram_vs_standard_ns_speedup",
                        t_std / t_gram * 100, derived="ratio_x100"))

    # ---- composed share attribution (normalized like Table 2)
    s_kernel = 1 - sym / full
    s_owner = 1 - 1 / (replicated / asn.makespan(cm))
    s_batch = 1 - t_batched / (t_single * b)
    tot = s_kernel + s_owner + s_batch
    for name, s in (("symmetric_kernel", s_kernel),
                    ("owner_scheduling_lb", s_owner),
                    ("autotune_batching", s_batch)):
        rows.append(csv_row(f"table2/share/{name}", s / tot * 1e6,
                            derived="share_x1e4"))

    # ---- pluggable-variant orthogonalizer overhead
    rows.extend(_variant_rows(variant))
    return rows


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--variant", default="muonbp",
                    help="variant for the orthogonalizer-overhead rows")
    for r in run(variant=ap.parse_args().variant):
        print(r)
