"""Table 2 analogue: per-component breakdown of DMuon's optimizer-step
speedup, by disabling each component in isolation:

  symmetric Gram kernel — Gram-space symmetric products vs full-GEMM Gram
                          (FLOP-exact model + measured Gram-vs-standard time)
  owner + load balance  — one owner per matrix (makespan) vs replicated NS
  batching + autotune   — batched stacks vs per-matrix launches (measured)

plus (``--pipeline``) a stage-level breakdown of the bucketed optimizer
schedule (docs/DESIGN.md §6): stage_in (pack + owner all-to-all), compute
(momentum + NS on the local slice), publish (reshard back + scale/wd/lr) —
the three phases the pipeline overlaps, timed in isolation.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import record, record_to_csv, time_samples
from repro.core import load_balance
from repro.core.gram_ns import GramNSConfig, gram_newton_schulz, gram_ns_flops
from repro.core.newton_schulz import newton_schulz

CENSUS = {(256, 1024): 32, (256, 256): 64, (128, 512): 96}
RANKS = 16


def _variant_records(variants) -> list[dict]:
    """Orthogonalizer-phase cost of registered variants on one owner stack:
    the refresh step (full NS) vs the steady-state step (MuonBP's cached
    reuse, Dion2's warm-basis path; identical to refresh for stateless
    variants).  ``muon`` is always measured first as the baseline, and every
    other variant's refresh row carries a ``vs_muon=`` ratio quantifying the
    ortho-phase cost each backend saves (or pays) over the plain Gram path."""
    from repro.core import api
    from repro.core.muon import MuonConfig
    from repro.core.orthogonalize import make_orthogonalizer
    from repro.core.owner_comms import OwnerLayout, group_key_str

    ordered = ["muon"] + [v for v in dict.fromkeys(variants) if v != "muon"]
    x = jax.random.normal(jax.random.PRNGKey(2), (16, 128, 512)) * 0.02
    plan = api.dedicate_params({"w": x}, num_owners=1, strategy="greedy")
    stacks = {group_key_str("w"): x}

    recs: list[dict] = []
    muon_refresh_s = None
    for variant in ordered:
        spec = api.get_variant(variant)
        if spec.elementwise:
            continue
        mcfg = MuonConfig(variant=variant)
        layout = OwnerLayout(plan)
        ortho = make_orthogonalizer(spec.orthogonalizer, mcfg)
        state = ortho.init_state(layout, mcfg)

        fn = jax.jit(lambda sts, step, st, o=ortho, lo=layout, c=mcfg: o(
            sts, step=step, state=st, layout=lo, cfg=c))
        t_refresh = time_samples(fn, stacks, jnp.zeros((), jnp.int32), state)
        derived = ""
        if variant == "muon":
            muon_refresh_s = min(t_refresh)
        elif muon_refresh_s is not None:
            derived = f"vs_muon={min(t_refresh) / muon_refresh_s:.2f}x"
        recs.append(record("table2/variant/ortho_refresh", variant=variant,
                           samples_s=t_refresh, derived=derived))
        # steady state: advance past the refresh boundary (step % period
        # != 0 for MuonBP; a warm — nonzero — basis for Dion2)
        _, state1 = fn(stacks, jnp.zeros((), jnp.int32), state)
        t_steady = time_samples(fn, stacks, jnp.ones((), jnp.int32), state1)
        recs.append(record(
            "table2/variant/ortho_steady", variant=variant,
            samples_s=t_steady,
            derived=f"refresh/steady="
                    f"{min(t_refresh)/min(t_steady):.2f}x"))
    return recs


def _pipeline_records(variant: str, pipeline: str) -> list[dict]:
    """Stage-level cost of the bucketed schedule on a multi-bucket toy
    census: stage_in vs compute vs publish vs the whole pipelined step."""
    import numpy as np

    from repro.core import api
    from repro.core.muon import MuonConfig
    from repro.core.pipeline import BucketPipeline

    params = {f"w{i}": np.zeros((8, m, n), np.float32)
              for i, (m, n) in enumerate(sorted(CENSUS))}
    rng = jax.random.PRNGKey(3)
    grads = {p: jax.random.normal(jax.random.fold_in(rng, i),
                                  v.shape) * 0.02
             for i, (p, v) in enumerate(params.items())}
    plan = api.dedicate_params(params, num_owners=1, strategy="greedy")
    cfg = MuonConfig(variant=variant, pipeline=pipeline)
    spec = api.get_variant(cfg.variant)
    if spec.elementwise:
        return []
    pipe = BucketPipeline(plan, cfg, spec=spec)
    opt = api.Muon(plan, config=cfg)
    state = opt.init(params)
    recs = []

    stage = jax.jit(lambda g: pipe.stage_in_all(g))
    recs.append(record("table2/pipeline/stage_in", variant=variant,
                       pipeline=pipeline, samples_s=time_samples(stage,
                                                                 grads)))
    staged = stage(grads)
    comp = jax.jit(lambda st, s: pipe.run_staged(st, params, s)[:2])
    recs.append(record("table2/pipeline/compute_publish", variant=variant,
                       pipeline=pipeline,
                       samples_s=time_samples(comp, staged, state)))
    full = jax.jit(lambda g, s: opt.update(g, s, params))
    recs.append(record("table2/pipeline/full_step", variant=variant,
                       pipeline=pipeline,
                       samples_s=time_samples(full, grads, state)))
    return recs


DEFAULT_VARIANTS = ("muon", "dion2", "adamuon")


def run_records(variants=DEFAULT_VARIANTS,
                pipeline: str = "bucketed") -> list[dict]:
    recs: list[dict] = []
    cfg = GramNSConfig(num_steps=5)

    # ---- symmetric-kernel share (FLOP-exact; kernels halve every product)
    full = sym = 0.0
    for (m, n), c in CENSUS.items():
        f = gram_ns_flops(m, n, 5, batch=c)
        full += f["gram_full_gemm"]
        sym += f["gram_symmetric_kernel"]
    recs.append(record("table2/symmetric_kernel_flop_saving_pct",
                       value=(1 - sym / full) * 100, unit="pct",
                       derived="pct"))

    # ---- owner + LB: replicated cost vs balanced makespan
    cm = load_balance.analytic_cost_model(CENSUS)
    asn = load_balance.solve_greedy(CENSUS, cm, RANKS)
    replicated = sum(cm.per_matrix(s) * n for s, n in CENSUS.items())
    recs.append(record("table2/owner_lb_speedup",
                       value=replicated / asn.makespan(cm) * 100,
                       unit="ratio_x100", derived="ratio_x100"))
    r0 = load_balance.rank0(CENSUS, RANKS)
    recs.append(record("table2/rank0_ablation_slowdown",
                       value=r0.makespan(cm) / asn.makespan(cm) * 100,
                       unit="ratio_x100", derived="ratio_x100"))

    # ---- batching: measured batched stack vs per-matrix loop
    m, n, b = 128, 512, 16
    x = jax.random.normal(jax.random.PRNGKey(0), (b, m, n))
    fn_b = jax.jit(lambda v: gram_newton_schulz(v, cfg,
                                                assume_short_fat=True))
    t_batched = min(time_samples(fn_b, x))
    fn_1 = jax.jit(lambda v: gram_newton_schulz(v, cfg,
                                                assume_short_fat=True))
    t_single = min(time_samples(fn_1, x[:1]))
    recs.append(record("table2/batching_speedup",
                       value=(t_single * b) / t_batched * 100,
                       unit="ratio_x100", derived="ratio_x100"))

    # ---- gram vs standard NS (measured, fat matrices where gram wins)
    xf = jax.random.normal(jax.random.PRNGKey(1), (8, 256, 2048))
    t_gram = min(time_samples(jax.jit(
        lambda v: gram_newton_schulz(v, cfg, assume_short_fat=True)), xf))
    t_std = min(time_samples(jax.jit(
        lambda v: newton_schulz(v, num_steps=5)), xf))
    recs.append(record("table2/gram_vs_standard_ns_speedup",
                       value=t_std / t_gram * 100, unit="ratio_x100",
                       derived="ratio_x100"))

    # ---- composed share attribution (normalized like Table 2)
    s_kernel = 1 - sym / full
    s_owner = 1 - 1 / (replicated / asn.makespan(cm))
    s_batch = 1 - t_batched / (t_single * b)
    tot = s_kernel + s_owner + s_batch
    for name, s in (("symmetric_kernel", s_kernel),
                    ("owner_scheduling_lb", s_owner),
                    ("autotune_batching", s_batch)):
        recs.append(record(f"table2/share/{name}", value=s / tot * 100,
                           unit="pct", derived="share_pct"))

    # ---- pluggable-variant orthogonalizer overhead + pipeline stages
    variants = tuple(variants)
    recs.extend(_variant_records(variants))
    for v in dict.fromkeys(variants):
        recs.extend(_pipeline_records(v, pipeline))
    return recs


def run(variants=DEFAULT_VARIANTS, pipeline: str = "bucketed") -> list[str]:
    return [record_to_csv(r) for r in run_records(variants, pipeline)]


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--variant", action="append", default=None,
                    help="variant for the orthogonalizer-overhead rows; "
                         "repeatable (muon is always measured as baseline); "
                         "default: %s" % (DEFAULT_VARIANTS,))
    ap.add_argument("--pipeline", default="bucketed",
                    choices=["fused", "bucketed"],
                    help="schedule for the pipeline-stage rows")
    args = ap.parse_args()
    for r in run(variants=tuple(args.variant or DEFAULT_VARIANTS),
                 pipeline=args.pipeline):
        print(r)
