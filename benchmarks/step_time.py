"""Table 1 / Figure 8 analogue: optimizer-step and end-to-end step time for
DMuon vs gather-then-compute Muon (Muon-AG) vs AdamW.

Two parts:
  (a) measured — wall-clock of the three optimizer modes + full train step on
      this host (single CPU device, reduced workload, identical semantics);
  (b) derived  — per-rank optimizer time at 8..256 ranks from the measured
      per-(shape,batch) cost model, exactly the quantity Table 1 reports:
      vanilla = every rank runs NS for every matrix (gather-then-compute);
      DMuon   = makespan of the computation-aware assignment (each matrix
      once, balanced) — the redundancy removal + load balancing the paper
      attributes its speedup to.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import csv_row, time_fn
from repro import configs
from repro.core import api, load_balance
from repro.core.muon import MuonConfig
from repro.data.pipeline import DataConfig, batch_for_step
from repro.models import model_fns
from repro.train.step import init_state, make_train_step


def _setup(mode: str, variant: str = "muon"):
    cfg = configs.get("smollm-360m", reduced=True, n_layers=8, d_model=256,
                      n_heads=8, n_kv_heads=4, d_ff=704, vocab=2048,
                      remat=False)
    shapes = jax.eval_shape(lambda k: model_fns(cfg).init(cfg, k),
                            jax.random.PRNGKey(0))
    plan = api.dedicate_params(shapes, num_owners=1, strategy="greedy")
    opt = api.Muon(plan, config=MuonConfig(mode=mode, variant=variant))
    state = init_state(cfg, opt, jax.random.PRNGKey(0))
    step = make_train_step(cfg, opt, donate=False)
    dcfg = DataConfig(vocab=cfg.vocab, seq_len=128, global_batch=8)
    batch = batch_for_step(dcfg, 0)
    return cfg, plan, opt, state, step, batch


def run(variant: str = "muon") -> list[str]:
    rows = []
    steps = {}
    opt_times = {}
    for mode in ("owner", "gather", "adamw"):
        # the owner row carries the requested variant; the gather/adamw
        # baselines only support plain muon semantics
        cfg, plan, opt, state, step, batch = _setup(
            mode, variant if mode == "owner" else "muon")
        t_step = time_fn(step, state, batch)
        steps[mode] = t_step
        # optimizer-phase only: grads precomputed
        from repro.train.step import make_loss_fn
        grads = jax.jit(jax.grad(make_loss_fn(cfg)))(state.params, batch)
        upd = jax.jit(lambda g, s, p: opt.update(g, s, p))
        t_opt = time_fn(upd, grads, state.opt_state, state.params)
        opt_times[mode] = t_opt
        tag = mode if mode != "owner" or variant == "muon" \
            else f"{mode}[{variant}]"
        rows.append(csv_row(f"step_time/{tag}/optimizer", t_opt * 1e6))
        rows.append(csv_row(f"step_time/{tag}/end_to_end", t_step * 1e6))

    # derived ratios compare the owner row against the plain-muon baselines;
    # under a non-default variant that is a cross-algorithm ratio, so the
    # row names carry the variant tag to keep the CSV honest
    vtag = "" if variant == "muon" else f"[{variant}]"
    rows.append(csv_row(f"step_time/speedup_opt_owner{vtag}_vs_gather",
                        opt_times["gather"] / opt_times["owner"] * 100,
                        derived="ratio_x100"))
    rows.append(csv_row(f"step_time/overhead{vtag}_vs_adamw_pct",
                        (steps["owner"] - steps["adamw"])
                        / steps["adamw"] * 1e6,
                        derived="pct_x1e4"))

    # -------- derived scaling table (Table 1 / Fig 8 shape) --------------
    census = {}
    full_cfg = configs.get("qwen2.5-14b")
    shapes = jax.eval_shape(lambda k: model_fns(full_cfg).init(full_cfg, k),
                            jax.random.PRNGKey(0))
    plan = api.dedicate_params(shapes, num_owners=1, strategy="round_robin")
    for g in plan.groups.values():          # aggregate per-leaf groups by shape
        census[g.key] = census.get(g.key, 0) + g.count
    cm = load_balance.analytic_cost_model(census)
    total_once = sum(cm.per_matrix(s) * n for s, n in census.items())
    for ranks in (8, 16, 32, 64, 128, 256):
        asn = load_balance.solve_greedy(census, cm, ranks)
        dmuon_t = asn.makespan(cm)
        vanilla_t = total_once              # every rank runs ALL matrices
        rows.append(csv_row(
            f"table1/qwen2.5-14b/{ranks}ranks/dmuon_opt_ms",
            dmuon_t * 1e6, derived=f"speedup={vanilla_t/dmuon_t:.1f}x"))
    return rows


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--variant", default="muon",
                    help="optimizer variant for the owner-mode rows "
                         "(muon/normuon/muonbp/adamw; registry in core/api.py)")
    for r in run(variant=ap.parse_args().variant):
        print(r)
