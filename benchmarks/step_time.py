"""Table 1 / Figure 8 analogue: optimizer-step and end-to-end step time for
DMuon vs gather-then-compute Muon (Muon-AG) vs AdamW.

Three parts:
  (a) measured — wall-clock of the optimizer modes + full train step on this
      host (single CPU device, reduced workload, identical semantics), for
      both optimizer-step pipelines ('fused' one-phase vs 'bucketed'
      stage_in/compute/publish; docs/DESIGN.md §6) at accum_steps 1 and 4
      (the accumulation-overlapped schedule only exists at accum > 1);
  (b) derived  — the owner-vs-adamw overhead gap per pipeline (the paper's
      near-Adam headline, and the number the bucketed pipeline is meant to
      shrink on multi-bucket configs);
  (c) derived  — per-rank optimizer time at 8..256 ranks from the measured
      per-(shape,batch) cost model, exactly the quantity Table 1 reports.

The bench config is multi-bucket by construction (GQA kv projections give a
second Gram dimension), so the bucketed schedule has something to pipeline.
"""

from __future__ import annotations

import jax

from benchmarks.common import record, record_to_csv
from repro import configs
from repro.core import api, load_balance
from repro.core.muon import MuonConfig
from repro.data.pipeline import DataConfig, batch_for_step
from repro.models import model_fns
from repro.train.step import init_state, make_train_step

CONFIG_TAG = "smollm-360m-reduced"
ACCUMS = (1, 4)


def _setup(mode: str, variant: str = "muon", pipeline: str = "fused",
           accum_steps: int = 1):
    cfg = configs.get("smollm-360m", reduced=True, n_layers=8, d_model=256,
                      n_heads=8, n_kv_heads=4, d_ff=704, vocab=2048,
                      remat=False)
    shapes = jax.eval_shape(lambda k: model_fns(cfg).init(cfg, k),
                            jax.random.PRNGKey(0))
    plan = api.dedicate_params(shapes, num_owners=1, strategy="greedy")
    opt = api.Muon(plan, config=MuonConfig(mode=mode, variant=variant,
                                           pipeline=pipeline))
    state = init_state(cfg, opt, jax.random.PRNGKey(0))
    step = make_train_step(cfg, opt, donate=False, accum_steps=accum_steps)
    dcfg = DataConfig(vocab=cfg.vocab, seq_len=128, global_batch=8)
    batch = batch_for_step(dcfg, 0)
    return cfg, plan, opt, state, step, batch


def _measure_paired(cases, accum_steps: int, repeats: int) -> list[dict]:
    """Interleaved (paired) sampling across all cases of one accum level.

    The modes/pipelines being compared differ by tens of ms while the host
    drifts by more than that between block measurements — so sample them
    round-robin: one timed call of each case per round.  Slow drift then
    hits every case equally and the *relative* numbers (the quantity every
    derived row reports) stay meaningful.
    """
    import time

    built = []
    for mode, variant, pipe in cases:
        cfg, plan, opt, state, step, batch = _setup(mode, variant, pipe,
                                                    accum_steps)
        opt_fn = opt_args = None
        if accum_steps == 1:
            from repro.train.step import make_loss_fn
            grads = jax.jit(jax.grad(make_loss_fn(cfg)))(state.params, batch)
            opt_fn = jax.jit(lambda g, s, p, _o=opt: _o.update(g, s, p))
            opt_args = (grads, state.opt_state, state.params)
        built.append({"tag": (mode, variant, pipe), "step": step,
                      "args": (state, batch), "opt_fn": opt_fn,
                      "opt_args": opt_args, "t_step": [], "t_opt": []})
    for b in built:                                    # warmup (compile)
        jax.block_until_ready(b["step"](*b["args"]))
        jax.block_until_ready(b["step"](*b["args"]))
        if b["opt_fn"] is not None:
            jax.block_until_ready(b["opt_fn"](*b["opt_args"]))
    for _ in range(repeats):
        for b in built:
            t0 = time.perf_counter()
            jax.block_until_ready(b["step"](*b["args"]))
            b["t_step"].append(time.perf_counter() - t0)
            if b["opt_fn"] is not None:
                t0 = time.perf_counter()
                jax.block_until_ready(b["opt_fn"](*b["opt_args"]))
                b["t_opt"].append(time.perf_counter() - t0)
    recs = []
    for b in built:
        mode, variant, pipe = b["tag"]
        recs.append(record(f"step_time/end_to_end/accum{accum_steps}",
                           config=CONFIG_TAG, variant=variant, mode=mode,
                           pipeline=pipe, samples_s=b["t_step"]))
        if b["t_opt"]:
            recs.append(record("step_time/optimizer", config=CONFIG_TAG,
                               variant=variant, mode=mode, pipeline=pipe,
                               samples_s=b["t_opt"]))
    return recs


def _derived_pipeline_records(ranks: int = 16,
                              tokens_per_step: float = 2 ** 21) -> list[dict]:
    """Mesh-scale roofline model of the two optimizer schedules (derived —
    single-host wall clock cannot show comm/compute overlap; this is the
    same cost-model convention as the table1 rows).

    Per Gram bucket b on the qwen2.5-14b census at ``ranks`` owners:
      compute(b)  = bottleneck rank's Gram-NS time (measured-form cost model)
      comm(b)     = bottleneck rank's staged all-to-all time, bf16 payload
    fused     = Σ_b (comm_in + compute + comm_out)   (serialized phases)
    bucketed  = Σ_b max(compute(b), comm_out(b-1)) + comm_out(b_last):
                with accum prestaging every stage_in rides under the next
                microbatch's fwd/bwd (orders of magnitude longer), and each
                publish overlaps the next bucket's compute (docs/DESIGN.md
                §6) — only the final publish is exposed.
    The near-Adam headline = optimizer delta over a 6·P·tokens/chip roofline
    step time.
    """
    import numpy as np

    from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

    census = {}
    full_cfg = configs.get("qwen2.5-14b")
    shapes = jax.eval_shape(lambda k: model_fns(full_cfg).init(full_cfg, k),
                            jax.random.PRNGKey(0))
    plan = api.dedicate_params(shapes, num_owners=1, strategy="round_robin")
    for g in plan.groups.values():
        census[g.key] = census.get(g.key, 0) + g.count
    cm = load_balance.analytic_cost_model(census)
    asn = load_balance.solve_greedy(census, cm, ranks)
    counts = asn.counts()

    buckets: dict = {}
    for (m, n) in census:
        buckets.setdefault(m, []).append((m, n))
    sched = sorted(buckets.items(), key=lambda kv: -kv[0])

    compute_b, comm_b = [], []
    for _, shs in sched:
        loads = np.zeros(ranks)
        byts = np.zeros(ranks)
        for s in shs:
            for b, r in asn.chunks[s]:
                loads[r] += cm.cost(s, b)
            byts += counts[s] * s[0] * s[1] * 2          # bf16 payload
        compute_b.append(float(loads.max()))
        comm_b.append(float(byts.max()) * (ranks - 1) / ranks / ICI_BW)

    nb = len(sched)
    fused = sum(2 * c + t for c, t in zip(comm_b, compute_b))
    bucketed = sum(max(compute_b[i], comm_b[i - 1] if i > 0 else 0.0)
                   for i in range(nb)) + comm_b[-1]

    n_params = sum(m * n * c for (m, n), c in census.items())
    adamw = n_params / ranks * 28 / HBM_BW               # m,v,p,g @ fp32
    step_fb = 6 * n_params * tokens_per_step / (PEAK_FLOPS_BF16 * ranks)

    recs = [record(f"step_time/derived_mesh{ranks}/optimizer",
                   config="qwen2.5-14b", mode="adamw", value=adamw * 1e6,
                   derived="model_us")]
    for pipe, t in (("fused", fused), ("bucketed", bucketed)):
        recs.append(record(f"step_time/derived_mesh{ranks}/optimizer",
                           config="qwen2.5-14b", mode="owner", pipeline=pipe,
                           value=t * 1e6, derived="model_us"))
        recs.append(record(
            f"step_time/derived_mesh{ranks}/overhead_vs_adamw_pct",
            config="qwen2.5-14b", mode="owner", pipeline=pipe,
            value=(t - adamw) / (step_fb + adamw) * 100.0, unit="pct",
            derived="model_pct"))
    return recs


def run_records(variant: str = "muon", pipeline: str = "both",
                repeats: int = 15) -> list[dict]:
    pipelines = ("fused", "bucketed") if pipeline == "both" else (pipeline,)
    records: list[dict] = []
    for accum in ACCUMS:
        # the owner rows carry the requested variant and both pipelines;
        # the gather/adamw baselines only have the one-phase program
        cases = [("owner", variant, pipe) for pipe in pipelines]
        cases += [("gather", "muon", "fused"), ("adamw", "muon", "fused")]
        records.extend(_measure_paired(cases, accum, repeats))

    def med(name, mode, pipe, accum):
        for r in records:
            if (r["name"] == f"step_time/{name}/accum{accum}"
                    and r["mode"] == mode and r["pipeline"] == pipe):
                return r["median_us"]
        return None

    # the acceptance metric: how close each owner pipeline gets to the adamw
    # step time (pct overhead; the bucketed schedule should sit closer)
    for accum in ACCUMS:
        adamw = med("end_to_end", "adamw", "fused", accum)
        for pipe in pipelines:
            owner = med("end_to_end", "owner", pipe, accum)
            if owner is None or adamw is None:
                continue
            records.append(record(
                f"step_time/overhead_vs_adamw_pct/accum{accum}",
                config=CONFIG_TAG, variant=variant, mode="owner",
                pipeline=pipe, value=(owner - adamw) / adamw * 100.0,
                unit="pct", derived="pct"))

    records.extend(_derived_pipeline_records(ranks=16))

    # -------- derived scaling table (Table 1 / Fig 8 shape) --------------
    census = {}
    full_cfg = configs.get("qwen2.5-14b")
    shapes = jax.eval_shape(lambda k: model_fns(full_cfg).init(full_cfg, k),
                            jax.random.PRNGKey(0))
    plan = api.dedicate_params(shapes, num_owners=1, strategy="round_robin")
    for g in plan.groups.values():      # aggregate per-leaf groups by shape
        census[g.key] = census.get(g.key, 0) + g.count
    cm = load_balance.analytic_cost_model(census)
    total_once = sum(cm.per_matrix(s) * n for s, n in census.items())
    for ranks in (8, 16, 32, 64, 128, 256):
        asn = load_balance.solve_greedy(census, cm, ranks)
        dmuon_t = asn.makespan(cm)
        records.append(record(
            f"table1/qwen2.5-14b/{ranks}ranks/dmuon_opt_ms",
            config="qwen2.5-14b", mode="owner", value=dmuon_t * 1e6,
            unit="model_us", derived=f"speedup={total_once/dmuon_t:.1f}x"))
    return records


def run(variant: str = "muon", pipeline: str = "both") -> list[str]:
    return [record_to_csv(r) for r in run_records(variant, pipeline)]


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--variant", default="muon",
                    help="optimizer variant for the owner-mode rows "
                         "(muon/normuon/muonbp/adamw; registry in core/api.py)")
    ap.add_argument("--pipeline", default="both",
                    choices=["fused", "bucketed", "both"],
                    help="optimizer-step schedule for the owner-mode rows")
    args = ap.parse_args()
    for r in run(variant=args.variant, pipeline=args.pipeline):
        print(r)
