"""Validate BENCH_*.json files and diff them against committed baselines.

    PYTHONPATH=src python -m benchmarks.check_regression \
        --baseline BENCH_step_time.json --current /tmp/BENCH_step_time.json

Two jobs (both exercised by the CI benchmark-smoke job):

  schema   — every file must carry ``schema_version == BENCH_SCHEMA_VERSION``
             and records with the full field set (name/config/variant/mode/
             pipeline/median_us/p90_us/samples/unit/derived);
  regress  — measured records (``samples > 0``) shared between baseline and
             current are compared on ``median_us``; anything more than
             ``--threshold`` (default 10%) slower is flagged.  Derived and
             analytic rows (samples == 0) are schema-checked only — they are
             deterministic model outputs, not wall clock, and CI runners are
             noisy enough that absolute wall-clock diffs are advisory:
             ``--advisory`` downgrades regressions to warnings (the CI smoke
             job uses it; a quiet dev box can enforce).
"""

from __future__ import annotations

import argparse
import json
import sys
from typing import Dict, List, Tuple

from benchmarks.common import BENCH_SCHEMA_VERSION

REQUIRED_FIELDS = ("name", "config", "variant", "mode", "pipeline",
                   "median_us", "p90_us", "samples", "unit", "derived")

# measured rows of the serve suite additionally carry serving metrics
# (median decode-step time alone doesn't capture a scheduler regression)
SERVE_REQUIRED_FIELDS = ("ttft_ms", "tokens_per_sec")

# paged-variant serve rows also carry the pool accounting (a paged run
# that stops reporting occupancy/preemptions is a broken allocator)
PAGED_REQUIRED_FIELDS = ("pool_blocks", "frag_pct", "preemptions")

# measured rows of the soak suite carry the resilience latencies (step time
# alone doesn't capture a slow recovery or re-plan path)
SOAK_REQUIRED_FIELDS = ("recovery_ms", "rebalance_ms")

# breakdown variant rows must say which variant they measured, and every
# non-muon refresh row must carry its vs_muon ratio — that ratio IS the
# claim the committed baseline makes (e.g. dion2's ortho-cost reduction)
BREAKDOWN_VARIANT_PREFIX = "table2/variant/"
BREAKDOWN_BASELINE_VARIANT = "muon"


def load_and_validate(path: str) -> dict:
    """Parse one BENCH_*.json and enforce the schema; raises ValueError."""
    with open(path) as f:
        doc = json.load(f)
    if doc.get("schema_version") != BENCH_SCHEMA_VERSION:
        raise ValueError(
            f"{path}: schema_version={doc.get('schema_version')!r}, "
            f"expected {BENCH_SCHEMA_VERSION}")
    for top in ("suite", "host", "records"):
        if top not in doc:
            raise ValueError(f"{path}: missing top-level field {top!r}")
    if not isinstance(doc["records"], list) or not doc["records"]:
        raise ValueError(f"{path}: records must be a non-empty list")
    for i, rec in enumerate(doc["records"]):
        missing = [k for k in REQUIRED_FIELDS if k not in rec]
        if missing:
            raise ValueError(
                f"{path}: records[{i}] ({rec.get('name', '?')}) missing "
                f"fields {missing}")
        if rec["samples"] < 0 or (rec["samples"] > 0 and
                                  (rec["median_us"] < 0
                                   or rec["p90_us"] < 0)):
            # derived rows (samples == 0) may carry signed model values
            raise ValueError(
                f"{path}: records[{i}] ({rec['name']}) has negative values")
        if doc.get("suite") == "serve" and rec["samples"] > 0:
            missing = [k for k in SERVE_REQUIRED_FIELDS if k not in rec]
            if missing:
                raise ValueError(
                    f"{path}: records[{i}] ({rec['name']}) is a measured "
                    f"serve row missing fields {missing}")
            if any(rec[k] < 0 for k in SERVE_REQUIRED_FIELDS):
                raise ValueError(
                    f"{path}: records[{i}] ({rec['name']}) has negative "
                    f"serving metrics")
            if rec.get("variant") == "paged":
                missing = [k for k in PAGED_REQUIRED_FIELDS if k not in rec]
                if missing:
                    raise ValueError(
                        f"{path}: records[{i}] ({rec['name']}) is a paged "
                        f"serve row missing fields {missing}")
                if any(rec[k] < 0 for k in PAGED_REQUIRED_FIELDS):
                    raise ValueError(
                        f"{path}: records[{i}] ({rec['name']}) has "
                        f"negative pool accounting")
        if doc.get("suite") == "soak" and rec["samples"] > 0:
            missing = [k for k in SOAK_REQUIRED_FIELDS if k not in rec]
            if missing:
                raise ValueError(
                    f"{path}: records[{i}] ({rec['name']}) is a measured "
                    f"soak row missing fields {missing}")
            if any(rec[k] < 0 for k in SOAK_REQUIRED_FIELDS):
                raise ValueError(
                    f"{path}: records[{i}] ({rec['name']}) has negative "
                    f"resilience latencies")
        if (doc.get("suite") == "breakdown"
                and rec["name"].startswith(BREAKDOWN_VARIANT_PREFIX)):
            if not rec.get("variant"):
                raise ValueError(
                    f"{path}: records[{i}] ({rec['name']}) is a variant "
                    f"breakdown row with an empty 'variant' field")
            if (rec["name"] == BREAKDOWN_VARIANT_PREFIX + "ortho_refresh"
                    and rec["samples"] > 0
                    and rec["variant"] != BREAKDOWN_BASELINE_VARIANT
                    and "vs_muon=" not in rec.get("derived", "")):
                raise ValueError(
                    f"{path}: records[{i}] ({rec['name']}, variant="
                    f"{rec['variant']!r}) is a measured non-baseline "
                    f"refresh row missing its vs_muon= derived ratio")
    return doc


def _key(rec: dict) -> Tuple[str, str, str, str, str]:
    return (rec["name"], rec["config"], rec["variant"], rec["mode"],
            rec["pipeline"])


def diff(baseline: dict, current: dict,
         threshold_pct: float) -> Tuple[List[str], List[str]]:
    """Compare measured rows; returns (regressions, notes)."""
    base: Dict[Tuple, dict] = {_key(r): r for r in baseline["records"]}
    regressions, notes = [], []
    for rec in current["records"]:
        ref = base.get(_key(rec))
        tag = "/".join(t for t in _key(rec) if t)
        if ref is None:
            notes.append(f"new record (no baseline): {tag}")
            continue
        if rec["samples"] == 0 or ref["samples"] == 0:
            continue  # derived/analytic rows: schema-checked only
        if ref["median_us"] <= 0:
            continue
        delta = (rec["median_us"] - ref["median_us"]) / ref["median_us"] * 100
        line = (f"{tag}: {ref['median_us']:.1f}us -> "
                f"{rec['median_us']:.1f}us ({delta:+.1f}%)")
        if delta > threshold_pct:
            regressions.append(line)
        elif abs(delta) > threshold_pct:
            notes.append(f"improvement: {line}")
        # serve rows: a throughput DROP is a regression (higher is better)
        if ("tokens_per_sec" in rec and "tokens_per_sec" in ref
                and ref["tokens_per_sec"] > 0):
            drop = (ref["tokens_per_sec"] - rec["tokens_per_sec"]) \
                / ref["tokens_per_sec"] * 100
            tline = (f"{tag}: {ref['tokens_per_sec']:.1f} -> "
                     f"{rec['tokens_per_sec']:.1f} tokens/sec "
                     f"({-drop:+.1f}%)")
            if drop > threshold_pct:
                regressions.append(tline)
            elif drop < -threshold_pct:
                notes.append(f"improvement: {tline}")
    missing = set(base) - {_key(r) for r in current["records"]}
    for k in sorted(missing):
        notes.append("baseline record missing from current: "
                     + "/".join(t for t in k if t))
    return regressions, notes


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", action="append", default=[],
                    help="committed BENCH_*.json (repeatable, pairs with "
                         "--current in order)")
    ap.add_argument("--current", action="append", default=[],
                    help="freshly produced BENCH_*.json")
    ap.add_argument("--threshold", type=float, default=10.0,
                    help="flag measured rows slower than this pct (default "
                         "10)")
    ap.add_argument("--advisory", action="store_true",
                    help="report regressions but exit 0 (noisy CI runners)")
    args = ap.parse_args()
    if len(args.baseline) != len(args.current):
        raise SystemExit("--baseline/--current counts differ")
    if not args.current:
        raise SystemExit("nothing to check (pass --baseline/--current)")

    failed = False
    for bpath, cpath in zip(args.baseline, args.current):
        try:
            base = load_and_validate(bpath)
            cur = load_and_validate(cpath)
        except (OSError, ValueError, json.JSONDecodeError) as e:
            print(f"SCHEMA FAIL: {e}")
            failed = True
            continue
        if base["suite"] != cur["suite"]:
            print(f"SCHEMA FAIL: suite mismatch {base['suite']} vs "
                  f"{cur['suite']}")
            failed = True
            continue
        regressions, notes = diff(base, cur, args.threshold)
        print(f"[{cur['suite']}] {len(cur['records'])} records, "
              f"{len(regressions)} regression(s) over "
              f"{args.threshold:.0f}%")
        for n in notes:
            print(f"  note: {n}")
        for r in regressions:
            print(f"  REGRESSION: {r}")
        if regressions and not args.advisory:
            failed = True
    sys.exit(1 if failed else 0)


if __name__ == "__main__":
    main()
