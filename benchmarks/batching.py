"""Figure 7 analogue: per-matrix time of the batched Gram-NS execution,
normalized to single-matrix execution, across representative Gram-input
shapes.  Small near-square matrices underfill the device alone and gain the
most from batching; large rectangular ones saturate it and gain little."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import csv_row, time_fn
from repro.core.gram_ns import GramNSConfig, gram_newton_schulz

# (m, n) Gram-input shapes, scaled-down versions of the paper's sweep
SHAPES = [(128, 1408), (256, 1024), (256, 256), (128, 128), (64, 64)]
BATCHES = [1, 2, 4, 8, 16]


def run() -> list[str]:
    rows = []
    cfg = GramNSConfig(num_steps=5)
    fn = jax.jit(lambda x: gram_newton_schulz(x, cfg, assume_short_fat=True))
    for m, n in SHAPES:
        base = None
        for b in BATCHES:
            x = jax.random.normal(jax.random.PRNGKey(0), (b, m, n))
            t = time_fn(fn, x) / b          # per-matrix
            if base is None:
                base = t
            rows.append(csv_row(
                f"fig7/gram_ns/{m}x{n}/batch{b}/per_matrix", t * 1e6,
                derived=f"norm={t/base:.3f}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
