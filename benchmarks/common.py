"""Shared helpers for the benchmark harness.

Benchmarks run on the single CPU device (never set the 512-device flag
here).  Wall-clock numbers are for THIS host (XLA:CPU); mesh-scale numbers
are *derived* via the measured-cost model + the roofline artifacts, and are
labelled as such in the CSV (`derived` column) and in the JSON records
(`derived` field).

JSON trajectory: suites emit structured records (``record(...)``) which
``benchmarks/run.py`` writes as versioned ``BENCH_<suite>.json`` files —
the committed baselines CI diffs against (benchmarks/check_regression.py).
"""

from __future__ import annotations

import json
import platform
import time
from typing import Callable, List, Optional

import jax

BENCH_SCHEMA_VERSION = 1


def time_samples(fn: Callable, *args, repeats: int = 5,
                 warmup: int = 2) -> List[float]:
    """Wall-time samples in seconds (compiled path), after warmup."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    samples = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        samples.append(time.perf_counter() - t0)
    return samples


def time_fn(fn: Callable, *args, repeats: int = 3, warmup: int = 1) -> float:
    """Best-of-N wall time in seconds (compiled path)."""
    return min(time_samples(fn, *args, repeats=repeats, warmup=warmup))


def median(xs: List[float]) -> float:
    s = sorted(xs)
    n = len(s)
    mid = n // 2
    return s[mid] if n % 2 else 0.5 * (s[mid - 1] + s[mid])


def p90(xs: List[float]) -> float:
    s = sorted(xs)
    return s[min(len(s) - 1, int(round(0.9 * (len(s) - 1))))]


def csv_row(name: str, us_per_call: float, derived: str = "") -> str:
    return f"{name},{us_per_call:.1f},{derived}"


def record(name: str, *, config: str = "", variant: str = "",
           mode: str = "", pipeline: str = "",
           samples_s: Optional[List[float]] = None,
           value: Optional[float] = None, unit: str = "us",
           derived: str = "") -> dict:
    """One structured benchmark record (the BENCH_*.json schema).

    Wall-clock rows pass ``samples_s`` (seconds) and get median/p90 in µs;
    derived/analytic rows pass ``value`` directly with a ``derived`` tag.
    """
    rec = {"name": name, "config": config, "variant": variant,
           "mode": mode, "pipeline": pipeline, "unit": unit,
           "derived": derived}
    if samples_s is not None:
        rec["median_us"] = median(samples_s) * 1e6
        rec["p90_us"] = p90(samples_s) * 1e6
        rec["samples"] = len(samples_s)
    else:
        rec["median_us"] = float(value)
        rec["p90_us"] = float(value)
        rec["samples"] = 0
    return rec


def record_to_csv(rec: dict) -> str:
    tags = "/".join(t for t in (rec["mode"], rec["variant"], rec["pipeline"])
                    if t)
    name = f"{rec['name']}[{tags}]" if tags else rec["name"]
    return csv_row(name, rec["median_us"], rec["derived"])


def write_bench_json(path: str, suite: str, records: List[dict]) -> None:
    """Write one versioned BENCH_*.json file (schema below; validated by
    benchmarks/check_regression.py)."""
    doc = {
        "schema_version": BENCH_SCHEMA_VERSION,
        "suite": suite,
        "host": {"platform": platform.machine(),
                 "python": platform.python_version(),
                 "jax": jax.__version__,
                 "backend": jax.default_backend()},
        "records": records,
    }
    with open(path, "w") as f:
        json.dump(doc, f, indent=1, sort_keys=True)
        f.write("\n")
