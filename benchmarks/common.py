"""Shared helpers for the benchmark harness.

Benchmarks run on the single CPU device (never set the 512-device flag
here).  Wall-clock numbers are for THIS host (XLA:CPU); mesh-scale numbers
are *derived* via the measured-cost model + the roofline artifacts, and are
labelled as such in the CSV (`derived` column).
"""

from __future__ import annotations

import time
from typing import Callable

import jax


def time_fn(fn: Callable, *args, repeats: int = 3, warmup: int = 1) -> float:
    """Best-of-N wall time in seconds (compiled path)."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        best = min(best, time.perf_counter() - t0)
    return best


def csv_row(name: str, us_per_call: float, derived: str = "") -> str:
    return f"{name},{us_per_call:.1f},{derived}"
