"""Benchmark harness (deliverable d): one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run            # all benches
    PYTHONPATH=src python -m benchmarks.run step_time  # one bench

Prints ``name,us_per_call,derived`` CSV.  Wall-clock rows are measured on
this host (XLA:CPU, 1 device); mesh-scale rows are derived from the measured
cost model / dry-run artifacts and say so in ``derived``.
"""

from __future__ import annotations

import sys
import traceback


def main() -> None:
    from benchmarks import (batching, breakdown, load_balance_bench,
                            roofline_table, step_time)
    suites = {
        "step_time": step_time.run,          # Table 1 / Fig 8
        "breakdown": breakdown.run,          # Table 2
        "batching": batching.run,            # Fig 7
        "load_balance": load_balance_bench.run,   # §3.4
        "roofline": roofline_table.run,      # §Roofline (from dry-run)
    }
    want = sys.argv[1:] or list(suites)
    print("name,us_per_call,derived")
    failed = []
    for name in want:
        try:
            for row in suites[name]():
                print(row, flush=True)
        except Exception:  # noqa: BLE001 — report per-suite, keep going
            failed.append(name)
            traceback.print_exc()
    if failed:
        raise SystemExit(f"benchmark suites failed: {failed}")


if __name__ == "__main__":
    main()
