"""Benchmark harness (deliverable d): one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run            # all benches
    PYTHONPATH=src python -m benchmarks.run step_time  # one bench
    PYTHONPATH=src python -m benchmarks.run --json .   # also write BENCH_*.json

Prints ``name,us_per_call,derived`` CSV.  Wall-clock rows are measured on
this host (XLA:CPU, 1 device); mesh-scale rows are derived from the measured
cost model / dry-run artifacts and say so in ``derived``.

Suites that expose ``run_records()`` additionally emit versioned
``BENCH_<suite>.json`` files under ``--json DIR`` (schema in
benchmarks/common.py; validated + regression-diffed by
benchmarks/check_regression.py, which CI runs against the committed
baselines).
"""

from __future__ import annotations

import argparse
import os
import sys
import traceback


def main() -> None:
    from benchmarks import (batching, breakdown, load_balance_bench,
                            roofline_table, serve_bench, soak_bench,
                            step_time)
    from benchmarks.common import record_to_csv, write_bench_json
    suites = {
        "step_time": step_time,              # Table 1 / Fig 8
        "breakdown": breakdown,              # Table 2
        "batching": batching,                # Fig 7
        "load_balance": load_balance_bench,  # §3.4
        "roofline": roofline_table,          # §Roofline (from dry-run)
        "serve": serve_bench,                # continuous-batching tier
        "soak": soak_bench,                  # fault-injected resilience drill
    }
    ap = argparse.ArgumentParser()
    ap.add_argument("suite", nargs="*",
                    help=f"suites to run (default: all of {list(suites)})")
    ap.add_argument("--json", metavar="DIR", default=None,
                    help="also write BENCH_<suite>.json files to DIR for "
                         "suites with structured records")
    ap.add_argument("--pipeline", default="both",
                    choices=["fused", "bucketed", "both"],
                    help="optimizer-step schedule(s) for step_time")
    ap.add_argument("--repeats", type=int, default=None,
                    help="wall-clock samples per case for step_time "
                         "(default: the suite's baseline setting)")
    args = ap.parse_args()

    want = args.suite or list(suites)
    unknown = [s for s in want if s not in suites]
    if unknown:
        raise SystemExit(f"unknown suites {unknown}; have {list(suites)}")
    print("name,us_per_call,derived")
    failed = []
    for name in want:
        mod = suites[name]
        try:
            if hasattr(mod, "run_records"):
                kw = {}
                if name == "step_time":
                    kw["pipeline"] = args.pipeline
                    if args.repeats is not None:
                        kw["repeats"] = args.repeats
                records = mod.run_records(**kw)
                for rec in records:
                    print(record_to_csv(rec), flush=True)
                if args.json is not None:
                    path = os.path.join(args.json, f"BENCH_{name}.json")
                    write_bench_json(path, name, records)
                    print(f"# wrote {path}", file=sys.stderr)
            else:
                for row in mod.run():
                    print(row, flush=True)
        except Exception:  # noqa: BLE001 — report per-suite, keep going
            failed.append(name)
            traceback.print_exc()
    if failed:
        raise SystemExit(f"benchmark suites failed: {failed}")


if __name__ == "__main__":
    main()
