"""Serving-tier benchmark: continuous batching vs the one-shot baseline.

    PYTHONPATH=src python benchmarks/serve_bench.py --arch smollm-360m --json

Drives the ``repro.serve`` scheduler over CPU-scale analogues of the three
assigned serving shapes (reduced geometry, same roles):

    prefill_32k  prompt-heavy mix, short budgets      -> TTFT / prefill lane
    decode_32k   uniform short prompts, mixed budgets -> decode throughput;
                 also runs the static-batch one-shot baseline at the same
                 batch size for the head-to-head speedup row
    long_500k    one long prompt, chunked prefill     -> sub-quadratic archs
                 only (same skip rule as the dry-run grid)

Measured rows carry the usual median/p90 decode-step wall time *plus* the
serving fields (``ttft_ms``, ``tokens_per_sec``, ...) — the serve-suite
record shape benchmarks/check_regression.py validates and diffs (throughput
drops are regressions, just like step-time rises).
"""

from __future__ import annotations

import argparse
import os
import sys

if __name__ == "__main__" and __package__ is None:  # direct execution
    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))

import jax
import jax.numpy as jnp

from benchmarks.common import record, record_to_csv, write_bench_json

# serve-suite extra fields on measured rows (validated by check_regression)
SERVE_FIELDS = ("ttft_ms", "tokens_per_sec")

# ...and the pool-accounting fields paged rows additionally carry
PAGED_FIELDS = ("pool_blocks", "frag_pct", "preemptions")

# paged cache-block granularity (divides every scenario max_len, so paged
# and contiguous gather the same sequence length — bitwise-equal logits)
BLOCK_SIZE = 16

# CPU-scale stand-ins for the assigned serving shapes: same roles, reduced
# geometry (the real shapes are dry-run lowering targets, not CPU wall
# clock).  `n` scales with --requests except for the long-prompt lane.
SCENARIOS = {
    "prefill_32k": dict(prompt_lens=(24, 32), new_tokens=(2, 6),
                        max_len=48, chunk_len=None),
    "decode_32k": dict(prompt_lens=(8,), new_tokens=(4, 96),
                       budgets=(4, 4, 4, 96), max_len=112,
                       chunk_len=None),
    "long_500k": dict(prompt_lens=(96,), new_tokens=(2, 6),
                      max_len=112, chunk_len=16, n=2),
}

# the headline paged workload: bimodal long+short budgets.  Contiguous
# serves it at `--slots` full-length reservations; paged serves the SAME
# cache bytes (slots * max_len / BLOCK_SIZE blocks) spread over twice the
# decode slots, because short requests only hold the blocks they touch.
MIXED_SCENARIO = dict(prompt_lens=(8,), new_tokens=(4, 64),
                      budgets=(4, 4, 4, 64), max_len=80, chunk_len=None)


def _serve_record(name, *, config, mode, variant, summary):
    rec = record(name, config=config, mode=mode, variant=variant,
                 value=0.0)
    rec["median_us"] = summary["decode_step_us_median"]
    rec["p90_us"] = summary["decode_step_us_p90"]
    rec["samples"] = int(summary["decode_steps"])
    rec["ttft_ms"] = summary["ttft_ms_median"]
    rec["ttft_ms_p90"] = summary["ttft_ms_p90"]
    rec["tokens_per_sec"] = summary["tokens_per_sec"]
    rec["tokens_per_sec_per_chip"] = summary["tokens_per_sec_per_chip"]
    rec["slot_occupancy"] = summary["slot_occupancy"]
    rec["concurrent_mean"] = summary["concurrent_mean"]
    rec["derived"] = (f"tps={summary['tokens_per_sec']:.1f} "
                      f"ttft_ms={summary['ttft_ms_median']:.1f} "
                      f"occ={summary['slot_occupancy']:.2f}")
    if variant == "paged":
        rec["pool_blocks"] = int(summary.get("pool_blocks", 0))
        rec["frag_pct"] = summary.get("frag_pct", 0.0)
        rec["preemptions"] = int(summary.get("preemptions", 0))
        rec["derived"] += (f" pool={rec['pool_blocks']} "
                           f"frag={rec['frag_pct']:.1f}% "
                           f"preempt={rec['preemptions']}")
    return rec


def run_records(arch: str = "smollm-360m", requests: int = 24,
                num_slots: int = 8, seed: int = 0,
                kv: str = "contiguous") -> list:
    from repro import configs
    from repro.configs import shapes
    from repro.models import model_fns
    from repro.serve import (RequestQueue, Scheduler, ServeConfig,
                             run_oneshot)

    cfg = configs.get(arch, reduced=True)
    m = model_fns(cfg)
    params = jax.jit(lambda k: m.init(cfg, k))(jax.random.PRNGKey(0))
    enc_kw = {}
    if cfg.encdec:
        enc_kw = dict(frontend_dim=cfg.frontend_dim)
    variants = {"contiguous": ["continuous"], "paged": ["paged"],
                "both": ["continuous", "paged"]}[kv]
    if cfg.encdec and "paged" in variants:
        variants = [v for v in variants if v != "paged"]

    records = []
    for scen, spec in SCENARIOS.items():
        skip = shapes.cell_supported(cfg, scen)
        if skip is not None:
            records.append(record(f"serve/{scen}", config=arch,
                                  mode=scen, variant="skip",
                                  value=0.0, derived=skip))
            continue
        if cfg.encdec and spec["chunk_len"] is not None:
            records.append(record(f"serve/{scen}", config=arch,
                                  mode=scen, variant="skip", value=0.0,
                                  derived="enc-dec prefills in one shot; "
                                          "no chunked path"))
            continue
        n = spec.get("n", requests)
        if cfg.encdec:  # uniform enc_len across the workload
            spec = dict(spec, prompt_lens=spec["prompt_lens"][:1])

        def workload():
            return RequestQueue.synthetic(
                n, cfg.vocab, prompt_lens=spec["prompt_lens"],
                new_tokens=spec["new_tokens"],
                budgets=spec.get("budgets"), seed=seed, **enc_kw)

        for variant in variants:
            scfg = ServeConfig(num_slots=num_slots,
                               max_len=spec["max_len"],
                               chunk_len=spec["chunk_len"],
                               enc_len=(spec["prompt_lens"][0]
                                        if cfg.encdec else None),
                               kv=("paged" if variant == "paged"
                                   else "contiguous"),
                               block_size=BLOCK_SIZE)
            sched = Scheduler(cfg, params, scfg)
            sched.run(workload())      # warmup: compile everything
            summary = sched.run(workload()).summary()
            records.append(_serve_record(
                f"serve/{scen}", config=arch, mode=scen,
                variant=variant, summary=summary))

            if scen == "decode_32k" and variant == "continuous":
                # head-to-head vs static batching
                q = workload()
                q.poll(0.0)
                reqs = [q.pop_group(1)[0] for _ in range(len(q))]
                run_oneshot(cfg, params, reqs, batch=num_slots,
                            max_len=spec["max_len"])      # warmup
                base = run_oneshot(cfg, params, reqs, batch=num_slots,
                                   max_len=spec["max_len"]).summary()
                records.append(_serve_record(
                    f"serve/{scen}", config=arch, mode=scen,
                    variant="oneshot", summary=base))
                speedup = (summary["tokens_per_sec"]
                           / max(base["tokens_per_sec"], 1e-9))
                records.append(record(
                    "serve/speedup_vs_oneshot", config=arch, mode=scen,
                    value=speedup, unit="ratio",
                    derived=f"continuous/oneshot tokens_per_sec at "
                            f"batch={num_slots}"))

    if "paged" in variants and not cfg.encdec:
        records.extend(_mixed_records(cfg, params, requests=requests,
                                      num_slots=num_slots, seed=seed,
                                      enc_kw=enc_kw))
    return records


def _mixed_records(cfg, params, *, requests, num_slots, seed, enc_kw):
    """The headline paged-vs-contiguous comparison at EQUAL cache bytes:
    bimodal long+short budgets, contiguous at ``num_slots`` full-length
    rows vs paged spreading the same pool over ``2 * num_slots`` slots."""
    from repro.serve import RequestQueue, Scheduler, ServeConfig

    spec = MIXED_SCENARIO
    pool_blocks = num_slots * spec["max_len"] // BLOCK_SIZE

    def workload():
        return RequestQueue.synthetic(
            requests, cfg.vocab, prompt_lens=spec["prompt_lens"],
            new_tokens=spec["new_tokens"], budgets=spec["budgets"],
            seed=seed, **enc_kw)

    out = []
    summaries = {}
    for variant, scfg in [
        ("contiguous", ServeConfig(num_slots=num_slots,
                                   max_len=spec["max_len"])),
        ("paged", ServeConfig(num_slots=2 * num_slots,
                              max_len=spec["max_len"], kv="paged",
                              block_size=BLOCK_SIZE,
                              pool_blocks=pool_blocks)),
    ]:
        sched = Scheduler(cfg, params, scfg)
        sched.run(workload())          # warmup
        summaries[variant] = sched.run(workload()).summary()
        out.append(_serve_record(
            "serve/mixed_long_short", config=cfg.name,
            mode="mixed_long_short", variant=variant,
            summary=summaries[variant]))
    gain = (summaries["paged"]["concurrent_peak"]
            / max(summaries["contiguous"]["concurrent_peak"], 1))
    out.append(record(
        "serve/paged_concurrency_gain", config=cfg.name,
        mode="mixed_long_short", value=gain, unit="ratio",
        derived=f"paged/contiguous peak concurrent requests at equal "
                f"cache bytes ({pool_blocks} blocks x {BLOCK_SIZE} tok); "
                f"mean {summaries['paged']['concurrent_mean']:.1f} vs "
                f"{summaries['contiguous']['concurrent_mean']:.1f}"))
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--requests", type=int, default=24,
                    help="workload size for the mixed-traffic scenarios")
    ap.add_argument("--slots", type=int, default=8,
                    help="decode-batch slots (and one-shot batch size)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--kv", default="both",
                    choices=["contiguous", "paged", "both"],
                    help="cache layout(s) to run: contiguous per-slot "
                         "rows, the paged block pool, or both (paged adds "
                         "the mixed_long_short equal-memory comparison)")
    ap.add_argument("--json", nargs="?", const=".", default=None,
                    metavar="DIR", help="write BENCH_serve.json to DIR "
                                        "(default: repo root)")
    args = ap.parse_args()

    records = run_records(arch=args.arch, requests=args.requests,
                          num_slots=args.slots, seed=args.seed,
                          kv=args.kv)
    print("name,us_per_call,derived")
    for rec in records:
        print(record_to_csv(rec), flush=True)
    if args.json is not None:
        path = os.path.join(args.json, "BENCH_serve.json")
        write_bench_json(path, "serve", records)
        print(f"# wrote {path}", file=sys.stderr)


if __name__ == "__main__":
    main()
