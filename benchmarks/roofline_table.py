"""§Roofline table generator: reads the dry-run artifacts under
experiments/dryrun/ and emits the per-(arch × shape × mesh) three-term
roofline rows (also consumed to build EXPERIMENTS.md)."""

from __future__ import annotations

import glob
import json
import os

from benchmarks.common import csv_row

RESULT_DIR = os.path.abspath(os.path.join(
    os.path.dirname(os.path.abspath(__file__)), "..", "experiments",
    "dryrun"))


def load_cells(mesh: str = "single"):
    cells = []
    for fp in sorted(glob.glob(os.path.join(RESULT_DIR, mesh, "*.json"))):
        with open(fp) as f:
            cells.append(json.load(f))
    return cells


def run() -> list[str]:
    rows = []
    for mesh in ("single", "multi"):
        for c in load_cells(mesh):
            tag = f"roofline/{mesh}/{c['arch']}/{c['shape']}"
            if c.get("skipped"):
                rows.append(csv_row(tag + "/skipped", 0,
                                    derived=c["skipped"][:40]))
                continue
            if not c.get("ok"):
                rows.append(csv_row(tag + "/failed", 0,
                                    derived=c.get("error", "?")[:60]))
                continue
            r = c["roofline"]
            dom_s = max(r["compute_s"], r["memory_s"], r["collective_s"])
            rows.append(csv_row(
                tag, dom_s * 1e6,
                derived=(f"dom={r['dominant']} c={r['compute_s']:.4f} "
                         f"m={r['memory_s']:.4f} x={r['collective_s']:.4f} "
                         f"useful={r['useful_ratio']:.2f} "
                         f"hbm={c['hbm_utilization']:.2f}")))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
