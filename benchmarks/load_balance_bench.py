"""§3.4 benchmark: owner-assignment quality per strategy on every assigned
architecture's real shape census (analytic TPU cost model), plus MILP vs
greedy solve time."""

from __future__ import annotations

import time

import jax

from benchmarks.common import csv_row
from repro import configs
from repro.core import api, load_balance
from repro.models import model_fns

RANKS = 64


def census_for(arch_id: str):
    cfg = configs.get(arch_id)
    shapes = jax.eval_shape(lambda k: model_fns(cfg).init(cfg, k),
                            jax.random.PRNGKey(0))
    plan = api.dedicate_params(shapes, num_owners=1, strategy="round_robin")
    census = {}
    for g in plan.groups.values():          # aggregate per-leaf groups by shape
        census[g.key] = census.get(g.key, 0) + g.count
    return census


def run() -> list[str]:
    rows = []
    for arch in ("qwen2.5-14b", "kimi-k2-1t-a32b", "hymba-1.5b"):
        census = census_for(arch)
        cm = load_balance.analytic_cost_model(census)
        lower = sum(cm.per_matrix(s) * n for s, n in census.items()) / RANKS
        for strat in ("load_balance", "greedy", "lpt", "round_robin",
                      "rank0"):
            t0 = time.perf_counter()
            asn = load_balance.assign(census, RANKS, strategy=strat,
                                      cost_model=cm, s_thr=2000)
            dt = time.perf_counter() - t0
            mk = asn.makespan(cm)
            rows.append(csv_row(
                f"lb/{arch}/{strat}/makespan", mk * 1e6,
                derived=f"vs_lower_bound={mk/lower:.2f}x solve={dt:.3f}s"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
