"""Continuous-batching scheduler: interleaved prefill + batched decode.

The loop (MaxText ``offline_inference`` style, adapted to this repo's
functional prefill/decode factories in ``train/serve.py``):

    poll queue -> prefill waiting requests into free slots -> one batched
    decode step over ALL slots (per-slot positions) -> sample / advance /
    evict finished -> repeat

Prefill policy: ready requests with the *same* prompt length pack into one
batched prefill call (up to ``prefill_pack``); prompts longer than
``chunk_len`` stream through ``prefill_chunk_fn`` in ``chunk_len``-token
pieces (the long_500k path) and occupy the prefill lane alone.  Decode
runs at the fixed slot batch with the vector-``pos`` decode path, so every
slot advances at its own depth — a slot's token stream is bit-identical to
the same prompt decoded solo (tests/test_serve.py pins this).

``run_oneshot`` is the pre-continuous-batching baseline (the old
``examples/serve_decode.py`` loop): FIFO rounds of ``batch`` requests
prefilled together and decoded in lockstep until the slowest request in
the round finishes — the padding steps it wastes are exactly what slot
recycling reclaims.
"""

from __future__ import annotations

import dataclasses
import functools
import time
from typing import Any, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import model_fns
from repro.serve.metrics import ServeMetrics
from repro.serve.queue import Request, RequestQueue
from repro.serve.slots import SlotManager
from repro.train import serve as serve_fns


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    """Serving-harness knobs (decode batch geometry + prefill policy)."""
    num_slots: int = 8
    max_len: int = 128            # per-slot cache rows (prefix+prompt+new)
    prefill_pack: int = 4         # max equal-length prompts per prefill
    chunk_len: Optional[int] = None   # chunked prefill above this length
    cache_dtype: Any = jnp.bfloat16
    enc_len: Optional[int] = None     # enc-dec: uniform encoder length
    record_logits: bool = False       # keep per-token logits (parity tests)


def _donate(*idx):
    """Buffer donation helps on accelerators; CPU warns and ignores it."""
    return idx if jax.default_backend() != "cpu" else ()


class Scheduler:
    """One model, one fixed decode batch, many requests."""

    def __init__(self, cfg, params, scfg: ServeConfig = ServeConfig(), *,
                 mesh=None):
        self.cfg = cfg
        self.params = params
        self.scfg = scfg
        self.prefix = (cfg.frontend_len
                       if cfg.frontend is not None and not cfg.encdec else 0)
        self.slots = SlotManager(cfg, scfg.num_slots, scfg.max_len,
                                 cache_dtype=scfg.cache_dtype,
                                 enc_len=scfg.enc_len)
        if mesh is not None:  # pin the slot cache to its serving layout
            self.slots.cache = jax.device_put(
                self.slots.cache,
                serve_fns.cache_shardings(cfg, self.slots.cache, mesh))

        dt = scfg.cache_dtype
        if cfg.encdec:
            self._prefill = jax.jit(lambda p, t, f: serve_fns.prefill_fn(
                cfg, p, t, scfg.max_len, cache_dtype=dt, frames=f))
        elif cfg.frontend == "patch":
            self._prefill = jax.jit(lambda p, t, f: serve_fns.prefill_fn(
                cfg, p, t, scfg.max_len, cache_dtype=dt, patches=f))
        elif cfg.frontend == "frame":
            self._prefill = jax.jit(lambda p, t, f: serve_fns.prefill_fn(
                cfg, p, t, scfg.max_len, cache_dtype=dt, frames=f))
        else:
            self._prefill = jax.jit(lambda p, t: serve_fns.prefill_fn(
                cfg, p, t, scfg.max_len, cache_dtype=dt))
        m = model_fns(cfg)
        if not cfg.encdec:
            self._fresh_cache = jax.jit(
                lambda: m.init_cache(cfg, 1, scfg.max_len, dt))
            self._chunk = jax.jit(
                lambda p, t, c, pos: serve_fns.prefill_chunk_fn(
                    cfg, p, t, c, pos),
                donate_argnums=_donate(2))
        self._decode = jax.jit(
            lambda p, t, c, pos: serve_fns.decode_fn(cfg, p, t, c, pos),
            donate_argnums=_donate(2))

    # ------------------------------------------------------------- prefill

    def _prefill_group(self, group: List[Request]):
        """Batched prefill of equal-length prompts -> (logits, cache)."""
        toks = jnp.asarray(np.stack([r.tokens for r in group]))
        if self.cfg.encdec or self.cfg.frontend is not None:
            frames = jnp.asarray(np.stack([r.frames for r in group]))
            return self._prefill(self.params, toks, frames)
        return self._prefill(self.params, toks)

    def _prefill_chunked(self, req: Request):
        """Stream one long prompt through the cache in chunk_len pieces."""
        c = self.scfg.chunk_len
        cache = self._fresh_cache()
        toks = np.asarray(req.tokens)[None]
        logits = None
        for off in range(0, req.prompt_len, c):
            logits, cache = self._chunk(
                self.params, jnp.asarray(toks[:, off:off + c]), cache,
                jnp.asarray(off, jnp.int32))
        return logits, cache

    def _admit(self, group: List[Request], metrics: ServeMetrics,
               t0: float, chunked: bool) -> None:
        if chunked:
            logits, rcache = self._prefill_chunked(group[0])
        else:
            logits, rcache = self._prefill_group(group)
        first = np.asarray(jnp.argmax(logits, -1), np.int32)
        logits_np = (np.asarray(logits)
                     if self.scfg.record_logits else None)
        now = time.perf_counter() - t0
        metrics.prefill_s.append(now)
        for row, r in enumerate(group):
            pos = r.prompt_len + self.prefix
            i = self.slots.insert(r, rcache, row, int(first[row]), pos)
            metrics.on_admit(r, now, int(first[row]),
                             logits_np[row] if logits_np is not None
                             else None)
            if (r.max_new_tokens <= 1
                    or (r.eos_id is not None and first[row] == r.eos_id)):
                metrics.on_done(r.rid, now)
                self.slots.evict(i)

    # -------------------------------------------------------------- decode

    def _decode_step(self, metrics: ServeMetrics, t0: float) -> None:
        slots = self.slots
        for i, s in slots.active():     # cache-exhausted: truncate
            if slots.out_of_cache(i):
                metrics.on_done(s.request.rid, time.perf_counter() - t0)
                slots.evict(i)
        n_active = slots.num_active
        if n_active == 0:
            return
        t_start = time.perf_counter()
        logits, slots.cache = self._decode(
            self.params, jnp.asarray(slots.tok), slots.cache,
            jnp.asarray(slots.pos))
        nxt = np.asarray(jnp.argmax(logits, -1), np.int32)   # host sync
        metrics.on_decode_step(time.perf_counter() - t_start, n_active)
        logits_np = np.asarray(logits) if self.scfg.record_logits else None
        now = time.perf_counter() - t0
        for i, s in slots.active():
            tok = int(nxt[i])
            slots.advance(i, tok)
            r = s.request
            metrics.on_token(r.rid, tok,
                             logits_np[i] if logits_np is not None else None)
            if (s.generated >= r.max_new_tokens
                    or (r.eos_id is not None and tok == r.eos_id)):
                metrics.on_done(r.rid, now)
                slots.evict(i)

    # ----------------------------------------------------------------- run

    def run(self, queue: RequestQueue) -> ServeMetrics:
        """Serve the queue to completion; returns the metrics sink."""
        metrics = ServeMetrics(self.scfg.num_slots)
        t0 = time.perf_counter()
        while True:
            now = time.perf_counter() - t0
            queue.poll(now)
            while self.slots.num_free > 0 and queue.num_ready > 0:
                cap = min(self.slots.num_free, self.scfg.prefill_pack)
                group = queue.pop_group(cap, self.scfg.chunk_len)
                chunked = (self.scfg.chunk_len is not None
                           and group[0].prompt_len > self.scfg.chunk_len)
                self._admit(group, metrics, t0, chunked)
            if self.slots.num_active == 0:
                if queue.drained:
                    break
                nxt = queue.next_arrival()
                if nxt is not None:   # idle until the next arrival
                    time.sleep(min(max(nxt - (time.perf_counter() - t0),
                                       0.0), 0.005))
                continue
            self._decode_step(metrics, t0)
        metrics.wall_s = time.perf_counter() - t0
        return metrics


# ------------------------------------------------------- one-shot baseline

@functools.lru_cache(maxsize=None)
def _oneshot_fns(cfg, max_len: int, dt):
    """jit closures for the baseline, cached so repeated runs (warmup,
    then measurement) hit the same compiled executables."""
    if cfg.encdec or cfg.frontend is not None:
        key = "patches" if cfg.frontend == "patch" else "frames"
        prefill = jax.jit(lambda p, t, f: serve_fns.prefill_fn(
            cfg, p, t, max_len, cache_dtype=dt, **{key: f}))
    else:
        prefill = jax.jit(lambda p, t: serve_fns.prefill_fn(
            cfg, p, t, max_len, cache_dtype=dt))
    decode = jax.jit(lambda p, t, c, pos: serve_fns.decode_fn(
        cfg, p, t, c, pos), donate_argnums=_donate(2))
    return prefill, decode


def run_oneshot(cfg, params, requests: List[Request], batch: int,
                max_len: int, *, cache_dtype=jnp.bfloat16) -> ServeMetrics:
    """Static-batch baseline: FIFO rounds of ``batch`` requests, each
    prefilled together and decoded in lockstep for the round's largest
    budget.  Requires a uniform prompt length (the old example's setting);
    only requested tokens count toward throughput — the lockstep padding
    is the waste continuous batching removes."""
    lens = {r.prompt_len for r in requests}
    if len(lens) != 1:
        raise ValueError(f"one-shot baseline needs uniform prompts: {lens}")
    prefix = cfg.frontend_len \
        if cfg.frontend is not None and not cfg.encdec else 0
    prefill, decode = _oneshot_fns(cfg, max_len, cache_dtype)

    metrics = ServeMetrics(batch)
    t0 = time.perf_counter()
    for start in range(0, len(requests), batch):
        rnd = requests[start:start + batch]
        S = rnd[0].prompt_len
        toks = jnp.asarray(np.stack([r.tokens for r in rnd]))
        if cfg.encdec or cfg.frontend is not None:
            frames = jnp.asarray(np.stack([r.frames for r in rnd]))
            logits, cache = prefill(params, toks, frames)
        else:
            logits, cache = prefill(params, toks)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        first = np.asarray(tok)
        now = time.perf_counter() - t0
        for row, r in enumerate(rnd):
            metrics.on_admit(r, now, int(first[row]))
            if r.max_new_tokens <= 1:
                metrics.on_done(r.rid, now)
        steps = max(r.max_new_tokens for r in rnd) - 1
        for i in range(steps):
            t_start = time.perf_counter()
            logits, cache = decode(params, tok, cache,
                                   jnp.asarray(S + prefix + i, jnp.int32))
            tok = jnp.argmax(logits, -1).astype(jnp.int32)
            nxt = np.asarray(tok)
            live = [r for r in rnd if r.max_new_tokens > i + 1]
            metrics.on_decode_step(time.perf_counter() - t_start, len(live))
            now = time.perf_counter() - t0
            for row, r in enumerate(rnd):
                if r.max_new_tokens > i + 1:   # still within budget
                    metrics.on_token(r.rid, int(nxt[row]))
                    if r.max_new_tokens == i + 2:
                        metrics.on_done(r.rid, now)
    metrics.wall_s = time.perf_counter() - t0
    return metrics
