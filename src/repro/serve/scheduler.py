"""Continuous-batching scheduler: interleaved prefill + batched decode.

The loop (MaxText ``offline_inference`` style, adapted to this repo's
functional prefill/decode factories in ``train/serve.py``):

    poll queue -> prefill waiting requests into free slots -> one batched
    decode step over ALL slots (per-slot positions) -> sample / advance /
    evict finished -> repeat

Prefill policy: ready requests with the *same* prompt length pack into one
batched prefill call (up to ``prefill_pack``); prompts longer than
``chunk_len`` stream through ``prefill_chunk_fn`` in ``chunk_len``-token
pieces (the long_500k path) and occupy the prefill lane alone.  Decode
runs at the fixed slot batch with the vector-``pos`` decode path, so every
slot advances at its own depth — a slot's token stream is bit-identical to
the same prompt decoded solo (tests/test_serve.py pins this).

``run_oneshot`` is the pre-continuous-batching baseline (the old
``examples/serve_decode.py`` loop): FIFO rounds of ``batch`` requests
prefilled together and decoded in lockstep until the slowest request in
the round finishes — the padding steps it wastes are exactly what slot
recycling reclaims.
"""

from __future__ import annotations

import dataclasses
import functools
import time
from typing import Any, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import model_fns
from repro.serve.metrics import ServeMetrics
from repro.serve.paged import PagedSlotManager, PreemptedSlot
from repro.serve.queue import Request, RequestQueue
from repro.serve.slots import SlotManager
from repro.train import serve as serve_fns


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    """Serving-harness knobs (decode batch geometry + prefill policy)."""
    num_slots: int = 8
    max_len: int = 128            # per-slot cache rows (prefix+prompt+new)
    prefill_pack: int = 4         # max equal-length prompts per prefill
    chunk_len: Optional[int] = None   # chunked prefill above this length
    cache_dtype: Any = jnp.bfloat16
    enc_len: Optional[int] = None     # enc-dec: uniform encoder length
    record_logits: bool = False       # keep per-token logits (parity tests)
    # ---- paged KV allocator (serve/paged.py, docs/DESIGN.md §12) ----
    kv: str = "contiguous"            # "contiguous" | "paged"
    block_size: int = 16              # tokens per cache block (paged)
    pool_blocks: Optional[int] = None   # pool size; None = same bytes as
                                        # the contiguous reservation
    watermark: float = 0.05           # free-block fraction held back from
                                      # admission (preemption headroom)
    preempt_every: Optional[int] = None   # drill: force-preempt the
                                          # youngest slot every N decode
                                          # steps (tests; paged mode only)


def _donate(*idx):
    """Buffer donation helps on accelerators; CPU warns and ignores it."""
    return idx if jax.default_backend() != "cpu" else ()


class Scheduler:
    """One model, one fixed decode batch, many requests."""

    def __init__(self, cfg, params, scfg: ServeConfig = ServeConfig(), *,
                 mesh=None):
        self.cfg = cfg
        self.params = params
        self.scfg = scfg
        self.prefix = (cfg.frontend_len
                       if cfg.frontend is not None and not cfg.encdec else 0)
        if scfg.kv not in ("contiguous", "paged"):
            raise ValueError(f"kv must be contiguous|paged, got {scfg.kv!r}")
        self.paged = scfg.kv == "paged"
        if scfg.preempt_every is not None and not self.paged:
            raise ValueError("preempt_every drills need kv='paged' "
                             "(contiguous slots cannot resume)")
        if self.paged:
            self.slots: SlotManager = PagedSlotManager(
                cfg, scfg.num_slots, scfg.max_len,
                block_size=scfg.block_size, pool_blocks=scfg.pool_blocks,
                cache_dtype=scfg.cache_dtype, enc_len=scfg.enc_len)
        else:
            self.slots = SlotManager(cfg, scfg.num_slots, scfg.max_len,
                                     cache_dtype=scfg.cache_dtype,
                                     enc_len=scfg.enc_len)
        # paged slots round max_len up to block granularity; every staging
        # cache below must match so the gathered sequence length (and hence
        # the logits, bitwise) agrees with the contiguous reference
        self.max_len = self.slots.max_len
        # attention leaves actually pooled?  (pure-recurrent families keep
        # the contiguous cache and only gain preempt/resume machinery)
        self._use_tables = self.paged and self.slots.paged
        if self.paged and scfg.watermark > 0:
            self._wm = max(1, round(scfg.watermark * self.slots.pool.num_blocks))
        else:
            self._wm = 0
        self._resume: List[PreemptedSlot] = []   # preempted, awaiting blocks
        self._steps = 0                          # decode steps (drill clock)
        if mesh is not None:  # pin the slot cache to its serving layout
            if self._use_tables:
                raise NotImplementedError(
                    "paged pool sharding is follow-up work; serve paged "
                    "caches single-process for now")
            self.slots.cache = jax.device_put(
                self.slots.cache,
                serve_fns.cache_shardings(cfg, self.slots.cache, mesh))

        dt = scfg.cache_dtype
        ml = self.max_len
        if cfg.encdec:
            self._prefill = jax.jit(lambda p, t, f: serve_fns.prefill_fn(
                cfg, p, t, ml, cache_dtype=dt, frames=f))
        elif cfg.frontend == "patch":
            self._prefill = jax.jit(lambda p, t, f: serve_fns.prefill_fn(
                cfg, p, t, ml, cache_dtype=dt, patches=f))
        elif cfg.frontend == "frame":
            self._prefill = jax.jit(lambda p, t, f: serve_fns.prefill_fn(
                cfg, p, t, ml, cache_dtype=dt, frames=f))
        else:
            self._prefill = jax.jit(lambda p, t: serve_fns.prefill_fn(
                cfg, p, t, ml, cache_dtype=dt))
        m = model_fns(cfg)
        if not cfg.encdec:
            self._fresh_cache = jax.jit(
                lambda: m.init_cache(cfg, 1, ml, dt))
            self._chunk = jax.jit(
                lambda p, t, c, pos: serve_fns.prefill_chunk_fn(
                    cfg, p, t, c, pos),
                donate_argnums=_donate(2))
        self._decode = jax.jit(
            lambda p, t, c, pos: serve_fns.decode_fn(cfg, p, t, c, pos),
            donate_argnums=_donate(2))
        if self._use_tables:
            self._decode_paged = jax.jit(
                lambda p, t, c, pos, bt: serve_fns.decode_fn(
                    cfg, p, t, c, pos, block_tables=bt),
                donate_argnums=_donate(2))
            # hybrid recurrent leaves sit at the slot batch, so a batch-1
            # chunked prefill cannot stream into the live cache — hybrids
            # stage chunked prompts contiguously and scatter on insert
            self._direct_chunk = cfg.family != "hybrid"
            if self._direct_chunk:
                self._chunk_paged = jax.jit(
                    lambda p, t, c, pos, bt: serve_fns.prefill_chunk_fn(
                        cfg, p, t, c, pos, block_tables=bt),
                    donate_argnums=_donate(2))
        else:
            self._direct_chunk = False

    # ------------------------------------------------------------- prefill

    def _prefill_group(self, group: List[Request]):
        """Batched prefill of equal-length prompts -> (logits, cache)."""
        toks = jnp.asarray(np.stack([r.tokens for r in group]))
        if self.cfg.encdec or self.cfg.frontend is not None:
            frames = jnp.asarray(np.stack([r.frames for r in group]))
            return self._prefill(self.params, toks, frames)
        return self._prefill(self.params, toks)

    def _prefill_chunked(self, req: Request):
        """Stream one long prompt through the cache in chunk_len pieces."""
        c = self.scfg.chunk_len
        cache = self._fresh_cache()
        toks = np.asarray(req.tokens)[None]
        logits = None
        for off in range(0, req.prompt_len, c):
            logits, cache = self._chunk(
                self.params, jnp.asarray(toks[:, off:off + c]), cache,
                jnp.asarray(off, jnp.int32))
        return logits, cache

    def _prefill_chunked_paged(self, req: Request):
        """Stream one long prompt straight into pool blocks through its
        block table — no contiguous staging cache (the paged long-prompt
        admission path).  Returns (logits, table)."""
        c = self.scfg.chunk_len
        table = self.slots.new_table(req.prompt_len + 1)
        bt = jnp.asarray(table.padded()[None])
        toks = np.asarray(req.tokens)[None]
        logits = None
        for off in range(0, req.prompt_len, c):
            logits, self.slots.cache = self._chunk_paged(
                self.params, jnp.asarray(toks[:, off:off + c]),
                self.slots.cache, jnp.asarray(off, jnp.int32), bt)
        return logits, table

    def _admit(self, group: List[Request], metrics: ServeMetrics,
               t0: float, chunked: bool) -> None:
        table = None
        if chunked and self._direct_chunk:
            logits, table = self._prefill_chunked_paged(group[0])
        elif chunked:
            logits, rcache = self._prefill_chunked(group[0])
        else:
            logits, rcache = self._prefill_group(group)
        first = np.asarray(jnp.argmax(logits, -1), np.int32)
        logits_np = (np.asarray(logits)
                     if self.scfg.record_logits else None)
        now = time.perf_counter() - t0
        metrics.prefill_s.append(now)
        for row, r in enumerate(group):
            pos = r.prompt_len + self.prefix
            if table is not None:
                i = self.slots.insert_prefilled(r, table, int(first[row]),
                                                pos)
            else:
                i = self.slots.insert(r, rcache, row, int(first[row]), pos)
            metrics.on_admit(r, now, int(first[row]),
                             logits_np[row] if logits_np is not None
                             else None)
            if (r.max_new_tokens <= 1
                    or (r.eos_id is not None and first[row] == r.eos_id)):
                metrics.on_done(r.rid, now)
                self.slots.evict(i)

    # ----------------------------------------------------- preempt / resume

    def _requeue(self, ps: PreemptedSlot, metrics: ServeMetrics,
                 t0: float) -> None:
        metrics.on_preempt(ps.request.rid, time.perf_counter() - t0)
        self._resume.append(ps)
        self._resume.sort(key=lambda p: p.seq)   # seniority order

    def _admit_resumes(self, metrics: ServeMetrics, t0: float) -> None:
        """Re-admit preempted requests (before any new admission — they
        hold seniority and already consumed prefill work).  Attention
        families rebuild their cache by re-prefilling prompt + generated
        tokens (bitwise: prefill is chunk-split invariant); recurrent
        families restore the exact saved state rows without recompute."""
        while self._resume and self.slots.num_free > 0:
            ps = self._resume[0]
            r = ps.request
            # tokens the model has consumed so far (the last sampled token
            # has not been fed yet — it is the resumed slot's next input)
            n_fed = r.prompt_len + ps.generated - 1
            pos = n_fed + self.prefix
            if self._use_tables:
                need = self.slots.blocks_for(pos + 1)
                head = 0 if self.slots.num_active == 0 else self._wm
                if self.slots.pool.num_free < need + head:
                    break                     # wait for blocks to free up
            self._resume.pop(0)
            last = int(ps.tokens[-1])
            if not self.slots.paged:
                # pure-recurrent: exact O(1) state restore, no recompute
                self.slots.insert(r, None, 0, last, pos, resume=ps)
                continue
            toks = np.concatenate([
                np.asarray(r.tokens, np.int32),
                np.asarray(ps.tokens[:-1], np.int32)])
            req2 = dataclasses.replace(r, tokens=toks)
            if (self.scfg.chunk_len is not None
                    and len(toks) > self.scfg.chunk_len):
                if self._direct_chunk:
                    _, table = self._prefill_chunked_paged(req2)
                    self.slots.insert_prefilled(r, table, last, pos,
                                                resume=ps)
                    continue
                _, rcache = self._prefill_chunked(req2)
            else:
                _, rcache = self._prefill_group([req2])
            self.slots.insert(r, rcache, 0, last, pos, resume=ps)

    # -------------------------------------------------------------- decode

    def _decode_step(self, metrics: ServeMetrics, t0: float) -> None:
        slots = self.slots
        for i, s in slots.active():     # cache-exhausted: truncate
            if slots.out_of_cache(i):
                metrics.on_done(s.request.rid, time.perf_counter() - t0)
                slots.evict(i)
        if self.paged:
            pe = self.scfg.preempt_every
            if pe and self._steps and self._steps % pe == 0 \
                    and slots.num_active >= 2:
                # drill: force one preempt→requeue→resume cycle (the >=2
                # guard keeps the fleet progressing between drills)
                j = slots._youngest()
                self._requeue(slots.preempt(j), metrics, t0)
            # grow every table to cover its next write; preempt youngest
            # when the pool runs dry
            for ps in slots.prepare_decode():
                self._requeue(ps, metrics, t0)
        n_active = slots.num_active
        if n_active == 0:
            return
        t_start = time.perf_counter()
        if self._use_tables:
            logits, slots.cache = self._decode_paged(
                self.params, jnp.asarray(slots.tok), slots.cache,
                jnp.asarray(slots.pos), jnp.asarray(slots.block_tables()))
        else:
            logits, slots.cache = self._decode(
                self.params, jnp.asarray(slots.tok), slots.cache,
                jnp.asarray(slots.pos))
        nxt = np.asarray(jnp.argmax(logits, -1), np.int32)   # host sync
        self._steps += 1
        metrics.on_decode_step(time.perf_counter() - t_start, n_active)
        metrics.on_pool_sample(*slots.pool_stats())
        logits_np = np.asarray(logits) if self.scfg.record_logits else None
        now = time.perf_counter() - t0
        for i, s in slots.active():
            tok = int(nxt[i])
            slots.advance(i, tok)
            r = s.request
            metrics.on_token(r.rid, tok,
                             logits_np[i] if logits_np is not None else None)
            if (s.generated >= r.max_new_tokens
                    or (r.eos_id is not None and tok == r.eos_id)):
                metrics.on_done(r.rid, now)
                slots.evict(i)

    # ----------------------------------------------------------------- run

    def run(self, queue: RequestQueue) -> ServeMetrics:
        """Serve the queue to completion; returns the metrics sink."""
        metrics = ServeMetrics(self.scfg.num_slots)
        t0 = time.perf_counter()
        while True:
            now = time.perf_counter() - t0
            queue.poll(now)
            self._admit_resumes(metrics, t0)   # preempted hold seniority
            while self.slots.num_free > 0 and queue.num_ready > 0:
                head = queue.peek()
                pos0 = head.prompt_len + self.prefix
                if pos0 >= self.max_len:
                    # over-length: the prompt alone fills the cache.  Reject
                    # at admission (graceful) instead of dying in insert()
                    r = queue.pop_group(1, self.scfg.chunk_len)[0]
                    metrics.on_reject(r, time.perf_counter() - t0)
                    continue
                cap = min(self.slots.num_free, self.scfg.prefill_pack)
                if self._use_tables:
                    # watermark admission: only admit what the free pool
                    # covers, holding back headroom for in-flight growth
                    need = self.slots.blocks_for(pos0 + 1)
                    afford = (self.slots.pool.num_free - self._wm) // need
                    if afford < 1:
                        if (self.slots.num_active == 0
                                and not self._resume
                                and self.slots.pool.num_free >= need):
                            afford = 1     # progress guarantee
                        else:
                            break
                    cap = min(cap, afford)
                group = queue.pop_group(cap, self.scfg.chunk_len)
                chunked = (self.scfg.chunk_len is not None
                           and group[0].prompt_len > self.scfg.chunk_len)
                self._admit(group, metrics, t0, chunked)
            if self.slots.num_active == 0 and not self._resume:
                if queue.drained:
                    break
                nxt = queue.next_arrival()
                if nxt is not None:   # idle until the next arrival
                    time.sleep(min(max(nxt - (time.perf_counter() - t0),
                                       0.0), 0.005))
                continue
            self._decode_step(metrics, t0)
        metrics.wall_s = time.perf_counter() - t0
        return metrics


# ------------------------------------------------------- one-shot baseline

@functools.lru_cache(maxsize=None)
def _oneshot_fns(cfg, max_len: int, dt):
    """jit closures for the baseline, cached so repeated runs (warmup,
    then measurement) hit the same compiled executables."""
    if cfg.encdec or cfg.frontend is not None:
        key = "patches" if cfg.frontend == "patch" else "frames"
        prefill = jax.jit(lambda p, t, f: serve_fns.prefill_fn(
            cfg, p, t, max_len, cache_dtype=dt, **{key: f}))
    else:
        prefill = jax.jit(lambda p, t: serve_fns.prefill_fn(
            cfg, p, t, max_len, cache_dtype=dt))
    decode = jax.jit(lambda p, t, c, pos: serve_fns.decode_fn(
        cfg, p, t, c, pos), donate_argnums=_donate(2))
    return prefill, decode


def run_oneshot(cfg, params, requests: List[Request], batch: int,
                max_len: int, *, cache_dtype=jnp.bfloat16) -> ServeMetrics:
    """Static-batch baseline: FIFO rounds of ``batch`` requests, each
    prefilled together and decoded in lockstep for the round's largest
    budget.  Requires a uniform prompt length (the old example's setting);
    only requested tokens count toward throughput — the lockstep padding
    is the waste continuous batching removes."""
    lens = {r.prompt_len for r in requests}
    if len(lens) != 1:
        raise ValueError(f"one-shot baseline needs uniform prompts: {lens}")
    prefix = cfg.frontend_len \
        if cfg.frontend is not None and not cfg.encdec else 0
    prefill, decode = _oneshot_fns(cfg, max_len, cache_dtype)

    metrics = ServeMetrics(batch)
    t0 = time.perf_counter()
    for start in range(0, len(requests), batch):
        rnd = requests[start:start + batch]
        S = rnd[0].prompt_len
        toks = jnp.asarray(np.stack([r.tokens for r in rnd]))
        if cfg.encdec or cfg.frontend is not None:
            frames = jnp.asarray(np.stack([r.frames for r in rnd]))
            logits, cache = prefill(params, toks, frames)
        else:
            logits, cache = prefill(params, toks)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        first = np.asarray(tok)
        now = time.perf_counter() - t0
        for row, r in enumerate(rnd):
            metrics.on_admit(r, now, int(first[row]))
            if r.max_new_tokens <= 1:
                metrics.on_done(r.rid, now)
        steps = max(r.max_new_tokens for r in rnd) - 1
        for i in range(steps):
            t_start = time.perf_counter()
            logits, cache = decode(params, tok, cache,
                                   jnp.asarray(S + prefix + i, jnp.int32))
            tok = jnp.argmax(logits, -1).astype(jnp.int32)
            nxt = np.asarray(tok)
            live = [r for r in rnd if r.max_new_tokens > i + 1]
            metrics.on_decode_step(time.perf_counter() - t_start, len(live))
            now = time.perf_counter() - t0
            for row, r in enumerate(rnd):
                if r.max_new_tokens > i + 1:   # still within budget
                    metrics.on_token(r.rid, int(nxt[row]))
                    if r.max_new_tokens == i + 2:
                        metrics.on_done(r.rid, now)
    metrics.wall_s = time.perf_counter() - t0
    return metrics
