"""Paged KV-cache allocator: block-granular memory for the serving tier.

The contiguous ``SlotManager`` reserves a full ``max_len`` cache row per
slot, so one long request dictates the reservation for every short chat
request and the decode batch is capped by worst-case length.  This module
is the vLLM-style fix (PagedAttention, arXiv:2309.06180):

  ``BlockPool``        fixed pool of ``block_size``-token physical cache
                       blocks — O(1) LIFO alloc/free, refcounts, hard
                       double-free detection.  Physical id 0 is the
                       reserved *null block*: free decode slots idle
                       there, no live table ever maps it.
  ``BlockTable``       one request's logical→physical block map; grows
                       block-by-block as the request decodes, releases
                       wholesale on evict/preempt.
  ``PagedSlotManager`` drop-in ``SlotManager`` (insert / evict / advance /
                       out_of_cache) whose attention leaves live in a
                       (L, P, bs, ...) pool read through per-slot block
                       tables (models/transformer.py ``init_paged_cache``,
                       ``decode_step(..., block_tables=)``).  Recurrent
                       leaves (SSM conv/state, xLSTM memories) are O(1)
                       per slot and stay batch-contiguous; pure-recurrent
                       families keep the whole contiguous cache and gain
                       only the preempt/resume machinery.

Preemption: when the pool cannot cover the next decode write of every
active slot, the *youngest* slot (latest ``Slot.seq``) is evicted and its
sampled tokens (plus exact recurrent state, when the family has any) are
handed back to the scheduler for requeue-and-resume — attention caches
are rebuilt by re-prefilling prompt + generated tokens, which is bitwise
on attention-only families (tests/test_serve.py pins transformer, MLA and
SSM resume parity; hybrid recompute re-associates the ssm scan and is
approximate).  See docs/DESIGN.md §12.
"""

from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import model_fns
from repro.models.transformer import PAGED_CACHE_KEYS
from repro.serve.queue import Request
from repro.serve.slots import Slot, SlotManager, _write_row

NULL_BLOCK = 0


class PoolExhausted(RuntimeError):
    """Raised by BlockPool.alloc when no free block remains — the caller
    (PagedSlotManager.prepare_decode / the scheduler's watermark admission)
    turns this into preemption or held-back admission, never a crash."""


class BlockPool:
    """Fixed pool of ``num_blocks`` physical cache blocks, ids 1..num_blocks
    (0 is the null block, outside the pool).  LIFO free list for O(1)
    alloc/free; per-block refcounts so a block can be shared (prefix
    sharing / copy-on-write forks) and is returned to the free list only
    when its last reference drops."""

    def __init__(self, num_blocks: int):
        if num_blocks < 1:
            raise ValueError(f"pool needs >= 1 block, got {num_blocks}")
        self.num_blocks = num_blocks
        self._free: List[int] = list(range(num_blocks, 0, -1))
        self._ref = np.zeros(num_blocks + 1, np.int32)

    @property
    def num_free(self) -> int:
        return len(self._free)

    @property
    def num_live(self) -> int:
        return self.num_blocks - len(self._free)

    def alloc(self) -> int:
        if not self._free:
            raise PoolExhausted(f"all {self.num_blocks} blocks live")
        b = self._free.pop()
        self._ref[b] = 1
        return b

    def share(self, block: int) -> int:
        """Take an extra reference on a live block."""
        if self._ref[block] <= 0:
            raise ValueError(f"block {block} is not live")
        self._ref[block] += 1
        return block

    def free(self, block: int) -> None:
        """Drop one reference; recycle the block when none remain."""
        if block == NULL_BLOCK or not 1 <= block <= self.num_blocks:
            raise ValueError(f"block {block} is not a pool block")
        if self._ref[block] <= 0:
            raise ValueError(f"double free of block {block}")
        self._ref[block] -= 1
        if self._ref[block] == 0:
            self._free.append(block)


class BlockTable:
    """One request's logical→physical block map.  ``blocks[j]`` backs
    logical token positions [j·bs, (j+1)·bs); ``padded()`` is the fixed
    (max_blocks,) row the decode kernel gathers through, with unallocated
    entries on the null block."""

    def __init__(self, pool: BlockPool, max_blocks: int):
        self.pool = pool
        self.max_blocks = max_blocks
        self.blocks: List[int] = []

    @property
    def num_blocks(self) -> int:
        return len(self.blocks)

    def grow(self, n: int = 1) -> None:
        """Append ``n`` freshly allocated blocks (PoolExhausted bubbles up
        with the table left at its pre-failure length — no partial leak)."""
        if len(self.blocks) + n > self.max_blocks:
            raise ValueError(
                f"table at {len(self.blocks)}+{n} blocks exceeds max "
                f"{self.max_blocks}")
        for _ in range(n):
            self.blocks.append(self.pool.alloc())

    def ensure_blocks(self, n: int) -> int:
        """Grow to at least ``n`` blocks; returns how many were added."""
        add = n - len(self.blocks)
        if add > 0:
            self.grow(add)
        return max(add, 0)

    def release(self) -> None:
        """Free every block (evict / preempt); safe to call twice."""
        blocks, self.blocks = self.blocks, []
        for b in blocks:
            self.pool.free(b)

    def padded(self) -> np.ndarray:
        row = np.full(self.max_blocks, NULL_BLOCK, np.int32)
        row[:len(self.blocks)] = self.blocks
        return row


@dataclasses.dataclass
class PreemptedSlot:
    """Everything the scheduler needs to resume a preempted request:
    the original request, its sampled-token stream, and (for families with
    recurrent state) the exact per-slot state rows saved at preemption."""
    request: Request
    generated: int
    tokens: List[int]
    seq: int                      # original admission order (seniority)
    recurrent: Optional[Any] = None   # {leaf: (L, ...)} per-slot state rows


@functools.partial(
    jax.jit, static_argnums=(4,),
    donate_argnums=(0,) if jax.default_backend() != "cpu" else ())
def _scatter_blocks(pool_leaves, row_leaves, ids, row, bs: int):
    """Copy the first len(ids) blocks of batch row ``row`` of a contiguous
    prefilled cache into physical pool blocks ``ids`` (insert path).
    Retraces per distinct block count; block counts are few and small."""
    nb = ids.shape[0]

    def one(pl, rl):
        src = jax.lax.dynamic_index_in_dim(rl, row, axis=1,
                                           keepdims=False)[:, :nb * bs]
        src = src.reshape((rl.shape[0], nb, bs) + rl.shape[3:])
        return pl.at[:, ids].set(src.astype(pl.dtype))
    return jax.tree.map(one, pool_leaves, row_leaves)


class PagedSlotManager(SlotManager):
    """SlotManager whose sequence axis is block-granular.

    Same lifecycle surface (insert / evict / advance / out_of_cache) plus:
      * ``prepare_decode()`` — grow every active slot's table to cover its
        next write, preempting the youngest slots when the pool runs dry;
      * ``new_table()`` / ``insert_prefilled()`` — the chunked-prefill
        admission path that streams a long prompt straight into pool
        blocks (no contiguous staging cache);
      * ``block_tables()`` — the (num_slots, W) gather index the paged
        decode path consumes.

    ``max_len`` is rounded up to block granularity so the gathered
    (B, W·bs, ...) view has the same sequence length as a contiguous
    ``max_len`` cache — that equality is what keeps paged logits bitwise
    against the contiguous reference (docs/DESIGN.md §12)."""

    def __init__(self, cfg, num_slots: int, max_len: int, *,
                 block_size: int = 16, pool_blocks: Optional[int] = None,
                 cache_dtype=jnp.bfloat16, enc_len: Optional[int] = None):
        if cfg.encdec:
            raise NotImplementedError(
                "paged slots cover decoder-only families; enc-dec keeps "
                "the contiguous SlotManager")
        self.block_size = block_size
        self.blocks_per_slot = math.ceil(max_len / block_size)
        # ssm-family caches are O(1) recurrent state: nothing to page
        self.paged = cfg.family != "ssm"
        if pool_blocks is None:   # same reservation as the contiguous tier
            pool_blocks = num_slots * self.blocks_per_slot
        if self.paged and pool_blocks < self.blocks_per_slot:
            raise ValueError(
                f"pool of {pool_blocks} blocks cannot hold one full-length "
                f"request ({self.blocks_per_slot} blocks)")
        self.pool = BlockPool(pool_blocks)
        self.tables: List[Optional[BlockTable]] = [None] * num_slots
        super().__init__(cfg, num_slots,
                         self.blocks_per_slot * block_size,
                         cache_dtype=cache_dtype, enc_len=enc_len)

    def _alloc_cache(self, cache_dtype):
        m = model_fns(self.cfg)
        if not self.paged:
            return m.init_cache(self.cfg, self.num_slots, self.max_len,
                                cache_dtype)
        return m.init_paged_cache(self.cfg, self.num_slots,
                                  self.pool.num_blocks + 1,
                                  self.block_size, cache_dtype)

    # ------------------------------------------------------------- queries

    def blocks_for(self, n_tokens: int) -> int:
        return math.ceil(n_tokens / self.block_size)

    def block_tables(self) -> np.ndarray:
        """(num_slots, blocks_per_slot) int32 gather index for decode;
        free slots are all-null rows (their idle writes hit block 0)."""
        rows = np.full((self.num_slots, self.blocks_per_slot),
                       NULL_BLOCK, np.int32)
        for i, t in enumerate(self.tables):
            if t is not None:
                rows[i, :t.num_blocks] = t.blocks
        return rows

    def pool_stats(self):
        if not self.paged:
            return super().pool_stats()
        used_blocks = self.pool.num_live
        used = sum(int(self.pos[i]) for i, _ in self.active())
        return (used_blocks * self.block_size, used,
                self.pool.num_blocks, used_blocks)

    def _recurrent_keys(self) -> List[str]:
        return [k for k in self.cache if k not in PAGED_CACHE_KEYS]

    # ----------------------------------------------------------- lifecycle

    def new_table(self, n_tokens: int) -> BlockTable:
        """Allocate a table covering ``n_tokens`` logical positions before
        the slot exists (chunked prefill streams into it in place)."""
        t = BlockTable(self.pool, self.blocks_per_slot)
        t.grow(self.blocks_for(n_tokens))
        return t

    def insert(self, req: Request, row_cache, row: int,
               first_token: int, pos: int, *,
               resume: Optional[PreemptedSlot] = None) -> int:
        """Claim a slot: allocate blocks covering [0, pos], scatter row
        ``row`` of the contiguous prefilled ``row_cache`` into them, and
        copy its recurrent rows (batch axis 1) as before.  ``resume``
        restores a preempted request: the generated-token bookkeeping
        continues where it left off and saved recurrent state overwrites
        whatever the re-prefill produced (``row_cache=None`` skips the
        cache copy entirely — the pure-recurrent resume path)."""
        if not self._free:
            raise RuntimeError("no free slot (scheduler admitted too many)")
        if pos >= self.max_len:
            raise ValueError(f"prompt fills the cache: pos {pos} >= "
                             f"max_len {self.max_len}")
        table = None
        if self.paged:
            table = self.new_table(pos + 1)   # PoolExhausted bubbles up
        i = self._free.pop()
        if row_cache is not None:
            if self.paged:
                paged = {k: self.cache[k] for k in PAGED_CACHE_KEYS
                         if k in self.cache}
                paged = _scatter_blocks(
                    paged, {k: row_cache[k] for k in paged},
                    jnp.asarray(table.blocks, jnp.int32),
                    row, self.block_size)
                rec_keys = self._recurrent_keys()
                rec = _write_row(
                    {k: self.cache[k] for k in rec_keys},
                    {k: row_cache[k] for k in rec_keys},
                    jnp.asarray(i, jnp.int32),
                    jnp.asarray(row, jnp.int32)) if rec_keys else {}
                self.cache = {**self.cache, **paged, **rec}
            else:
                self.cache = _write_row(self.cache, row_cache,
                                        jnp.asarray(i, jnp.int32),
                                        jnp.asarray(row, jnp.int32))
        self.tables[i] = table
        self.pos[i] = pos
        self.tok[i] = first_token
        if resume is not None:
            self.slots[i] = Slot(request=req, generated=resume.generated,
                                 tokens=list(resume.tokens),
                                 seq=resume.seq)
            if resume.recurrent is not None:
                self._restore_recurrent(i, resume.recurrent)
        else:
            self._seq += 1
            self.slots[i] = Slot(request=req, generated=1,
                                 tokens=[int(first_token)], seq=self._seq)
        return i

    def insert_prefilled(self, req: Request, table: BlockTable,
                         first_token: int, pos: int, *,
                         resume: Optional[PreemptedSlot] = None) -> int:
        """Claim a slot whose blocks already hold the prompt — the chunked
        admission path prefilled straight into ``table`` via
        ``prefill_chunk(..., block_tables=)``."""
        if not self._free:
            raise RuntimeError("no free slot (scheduler admitted too many)")
        if pos >= self.max_len:
            raise ValueError(f"prompt fills the cache: pos {pos} >= "
                             f"max_len {self.max_len}")
        table.ensure_blocks(self.blocks_for(pos + 1))
        i = self._free.pop()
        self.tables[i] = table
        self.pos[i] = pos
        self.tok[i] = first_token
        if resume is not None:
            self.slots[i] = Slot(request=req, generated=resume.generated,
                                 tokens=list(resume.tokens),
                                 seq=resume.seq)
            if resume.recurrent is not None:
                self._restore_recurrent(i, resume.recurrent)
        else:
            self._seq += 1
            self.slots[i] = Slot(request=req, generated=1,
                                 tokens=[int(first_token)], seq=self._seq)
        return i

    def evict(self, i: int) -> Slot:
        s = super().evict(i)
        if self.tables[i] is not None:
            self.tables[i].release()
            self.tables[i] = None
        return s

    # ---------------------------------------------------------- preemption

    def _save_recurrent(self, i: int) -> Optional[Dict[str, Any]]:
        keys = self._recurrent_keys()
        if not keys:
            return None
        return {k: jax.tree.map(lambda a: a[:, i], self.cache[k])
                for k in keys}

    def _restore_recurrent(self, i: int, saved: Dict[str, Any]) -> None:
        sel = jnp.asarray(i, jnp.int32)
        for k, v in saved.items():
            self.cache[k] = jax.tree.map(
                lambda a, s: a.at[:, sel].set(s.astype(a.dtype)),
                self.cache[k], v)

    def preempt(self, i: int) -> PreemptedSlot:
        """Evict slot ``i`` but capture what resume needs: the sampled
        token stream (attention caches are rebuilt bitwise by re-prefill)
        and, for recurrent families, the exact per-slot state rows —
        O(1) per slot, the reason recurrent state is never paged."""
        s = self.slots[i]
        if s is None:
            raise ValueError(f"slot {i} already free")
        saved = self._save_recurrent(i)
        self.evict(i)
        return PreemptedSlot(request=s.request, generated=s.generated,
                             tokens=list(s.tokens), seq=s.seq,
                             recurrent=saved)

    def _youngest(self) -> Optional[int]:
        live = self.active()
        if not live:
            return None
        return max(live, key=lambda t: t[1].seq)[0]

    def prepare_decode(self) -> List[PreemptedSlot]:
        """Grow every active slot's table to cover its next write position,
        oldest slot first.  When the pool runs dry, preempt the youngest
        active slot and retry — each preemption frees >= 1 block, so this
        terminates; a lone slot can always reach max_len because the pool
        holds >= blocks_per_slot.  Returns the preempted requests for the
        scheduler to requeue."""
        preempted: List[PreemptedSlot] = []
        if not self.paged:
            return preempted
        for i, s in sorted(self.active(), key=lambda t: t[1].seq):
            if self.slots[i] is not s:    # preempted by an older slot
                continue
            need = self.blocks_for(int(self.pos[i]) + 1)
            while self.tables[i].num_blocks < need:
                try:
                    self.tables[i].grow()
                except PoolExhausted:
                    j = self._youngest()
                    preempted.append(self.preempt(j))
                    if j == i:
                        break
        return preempted
