"""Slot manager: live requests mapped onto a fixed decode batch.

The decode cache is allocated ONCE at ``num_slots`` batch rows and never
reshaped; requests come and go by writing/recycling batch rows (axis 1 of
every cache leaf — KV caches ``(L, B, S, KV, hd)``, MLA latents
``(L, B, S, r)``, SSM conv/state ``(L, B, K, di)`` / ``(L, B, di, ds)``,
xLSTM matrix memories ``(n, B, H, hd, hd)`` — the batch axis is uniform
across every model family, which is what lets one slot abstraction cover
KV growth *and* recurrent state).

Lifecycle:  ``insert`` claims a free slot and copies a prefilled batch-1
(or one row of a packed batch-P) cache into the slot's row; the slot then
decodes at its own position via the vector-``pos`` decode path.  ``evict``
(EOS / budget exhausted) just returns the slot to the free list — the
stale row is *recycled*, not zeroed, because ``insert`` overwrites every
leaf's full row and causal masking never reads rows past a slot's own
position.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import model_fns
from repro.serve.queue import Request


@dataclasses.dataclass
class Slot:
    """Host-side bookkeeping for one occupied decode-batch row."""
    request: Request
    generated: int = 0          # tokens sampled so far (prefill's counts)
    tokens: Optional[List[int]] = None
    seq: int = 0                # admission order (preemption picks youngest)

    def __post_init__(self):
        if self.tokens is None:
            self.tokens = []


@functools.partial(
    jax.jit,
    donate_argnums=(0,) if jax.default_backend() != "cpu" else ())
def _write_row(dcache, rcache, slot, row):
    """Copy batch row ``row`` of a prefilled cache into batch row ``slot``
    of the decode cache, for every leaf (axis 1 is batch everywhere)."""
    return jax.tree.map(
        lambda a, b: a.at[:, slot].set(b[:, row].astype(a.dtype)),
        dcache, rcache)


class SlotManager:
    """Fixed-batch decode cache + per-slot position/token bookkeeping."""

    def __init__(self, cfg, num_slots: int, max_len: int, *,
                 cache_dtype=jnp.bfloat16, enc_len: Optional[int] = None):
        self.cfg = cfg
        self.num_slots = num_slots
        self.max_len = max_len
        self.enc_len = enc_len
        self.cache = self._alloc_cache(cache_dtype)
        # per-slot decode state, consumed directly by the vector-pos decode:
        # pos[i] is the next cache write position, tok[i] the last sampled
        # token.  Free slots idle at pos 0 — their writes land in a row that
        # insert() fully overwrites before it is ever attended.
        self.pos = np.zeros(num_slots, np.int32)
        self.tok = np.zeros(num_slots, np.int32)
        self.slots: List[Optional[Slot]] = [None] * num_slots
        self._free: List[int] = list(range(num_slots - 1, -1, -1))
        self._seq = 0            # monotonic admission counter (Slot.seq)

    def _alloc_cache(self, cache_dtype):
        """Cache-layout hook: contiguous (L, B, S_max, ...) rows here;
        paged.PagedSlotManager overrides with the block-pool layout."""
        m = model_fns(self.cfg)
        if self.cfg.encdec:
            if self.enc_len is None:
                raise ValueError("enc-dec slots need a uniform enc_len")
            return m.init_cache(self.cfg, self.num_slots, self.max_len,
                                self.enc_len, cache_dtype)
        return m.init_cache(self.cfg, self.num_slots, self.max_len,
                            cache_dtype)

    # ------------------------------------------------------------- queries

    @property
    def num_free(self) -> int:
        return len(self._free)

    @property
    def num_active(self) -> int:
        return self.num_slots - len(self._free)

    def active(self) -> List[Tuple[int, Slot]]:
        return [(i, s) for i, s in enumerate(self.slots) if s is not None]

    def pool_stats(self) -> Tuple[int, int, int, int]:
        """(reserved_tokens, used_tokens, pool_blocks, used_blocks) for the
        occupancy/fragmentation metrics.  The contiguous tier reserves every
        slot's full max_len row up front, whether occupied or not — that
        worst-case reservation is exactly what paged.PagedSlotManager's
        block-granular accounting shrinks."""
        used = sum(int(self.pos[i]) for i, _ in self.active())
        return self.num_slots * self.max_len, used, 0, 0

    # ----------------------------------------------------------- lifecycle

    def insert(self, req: Request, row_cache, row: int,
               first_token: int, pos: int) -> int:
        """Claim a free slot for ``req``: copy row ``row`` of the prefilled
        ``row_cache`` into it and start decoding at ``pos`` (the prompt
        length, plus any frontend prefix).  Returns the slot index."""
        if not self._free:
            raise RuntimeError("no free slot (scheduler admitted too many)")
        if pos >= self.max_len:
            raise ValueError(f"prompt fills the cache: pos {pos} >= "
                             f"max_len {self.max_len}")
        i = self._free.pop()
        self.cache = _write_row(self.cache, row_cache,
                                jnp.asarray(i, jnp.int32),
                                jnp.asarray(row, jnp.int32))
        self.pos[i] = pos
        self.tok[i] = first_token
        self._seq += 1
        self.slots[i] = Slot(request=req, generated=1,
                             tokens=[int(first_token)], seq=self._seq)
        return i

    def evict(self, i: int) -> Slot:
        """Free slot ``i`` (EOS / budget reached).  The cache row is left
        in place and recycled by the next insert."""
        s = self.slots[i]
        if s is None:
            raise ValueError(f"slot {i} already free")
        self.slots[i] = None
        self.pos[i] = 0
        self.tok[i] = 0
        self._free.append(i)
        return s

    def advance(self, i: int, token: int) -> None:
        """Record one decoded token for slot ``i`` and move its write
        position forward."""
        s = self.slots[i]
        assert s is not None
        self.pos[i] += 1
        self.tok[i] = token
        s.generated += 1
        s.tokens.append(int(token))

    def out_of_cache(self, i: int) -> bool:
        """True when slot ``i``'s next write would run off the cache end —
        the scheduler must evict (max-token truncation) before decoding."""
        return int(self.pos[i]) >= self.max_len
