"""Serving metrics: per-request TTFT / latency plus fleet-level throughput.

The scheduler reports events (first token, decode tokens, completion,
decode-step wall times, slot occupancy samples); ``summary()`` folds them
into the numbers the BENCH_serve.json records carry — time-to-first-token,
per-token decode latency, tokens/sec (and per chip), and mean slot
occupancy.  Timestamps are seconds relative to the scheduler's t0.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax
import numpy as np


def _median(xs: List[float]) -> float:
    return float(np.median(xs)) if xs else 0.0


def _p90(xs: List[float]) -> float:
    return float(np.percentile(xs, 90)) if xs else 0.0


@dataclasses.dataclass
class RequestRecord:
    rid: int
    arrival: float
    prompt_len: int
    requested: int
    t_first: Optional[float] = None      # TTFT timestamp
    t_done: Optional[float] = None
    generated: int = 0
    tokens: Optional[List[int]] = None
    logits: Optional[List[np.ndarray]] = None   # parity capture (tests)
    rejected: bool = False               # over-length, never admitted
    preemptions: int = 0                 # paged pool preempt→resume cycles

    @property
    def ttft(self) -> float:
        return self.t_first - self.arrival

    @property
    def per_token_latency(self) -> float:
        """Mean decode latency per token after the first (0 for 1-token
        requests)."""
        if self.generated <= 1 or self.t_done is None:
            return 0.0
        return (self.t_done - self.t_first) / (self.generated - 1)


class ServeMetrics:
    """Event sink for one scheduler run."""

    def __init__(self, num_slots: int):
        self.num_slots = num_slots
        self.requests: Dict[int, RequestRecord] = {}
        self.decode_step_s: List[float] = []     # batched-step wall times
        self.prefill_s: List[float] = []         # per prefill call
        self.active_per_step: List[int] = []
        self.decode_steps = 0
        self.wall_s = 0.0
        self.preemptions = 0                     # fleet-level preempt count
        # per-decode-step (reserved_tokens, used_tokens, pool_blocks,
        # used_blocks) samples from SlotManager.pool_stats()
        self.pool_samples: List[tuple] = []

    # -------------------------------------------------------------- events

    def on_admit(self, req, now: float, first_token: int,
                 logits_row: Optional[np.ndarray] = None) -> None:
        rec = RequestRecord(rid=req.rid, arrival=req.arrival,
                            prompt_len=req.prompt_len,
                            requested=req.max_new_tokens,
                            t_first=now, generated=1,
                            tokens=[int(first_token)])
        if logits_row is not None:
            rec.logits = [logits_row]
        self.requests[req.rid] = rec

    def on_token(self, rid: int, token: int,
                 logits_row: Optional[np.ndarray] = None) -> None:
        rec = self.requests[rid]
        rec.generated += 1
        rec.tokens.append(int(token))
        if logits_row is not None:
            rec.logits.append(logits_row)

    def on_done(self, rid: int, now: float) -> None:
        self.requests[rid].t_done = now

    def on_reject(self, req, now: float) -> None:
        """Over-length request turned away at admission: recorded as done
        with the ``rejected`` marker, zero tokens, no TTFT."""
        self.requests[req.rid] = RequestRecord(
            rid=req.rid, arrival=req.arrival, prompt_len=req.prompt_len,
            requested=req.max_new_tokens, t_done=now, rejected=True)

    def on_preempt(self, rid: int, now: float) -> None:
        self.requests[rid].preemptions += 1
        self.preemptions += 1

    def on_decode_step(self, dt: float, n_active: int) -> None:
        self.decode_steps += 1
        self.decode_step_s.append(dt)
        self.active_per_step.append(n_active)

    def on_pool_sample(self, reserved: int, used: int,
                       pool_blocks: int, used_blocks: int) -> None:
        self.pool_samples.append((reserved, used, pool_blocks, used_blocks))

    # ------------------------------------------------------------- summary

    @property
    def total_generated(self) -> int:
        return sum(r.generated for r in self.requests.values())

    def summary(self) -> Dict[str, float]:
        ttfts = [r.ttft for r in self.requests.values()
                 if r.t_first is not None]
        per_tok = [r.per_token_latency for r in self.requests.values()
                   if r.generated > 1]
        occ = (float(np.mean(self.active_per_step)) / self.num_slots
               if self.active_per_step else 0.0)
        toks = self.total_generated
        tps = toks / self.wall_s if self.wall_s > 0 else 0.0
        out = {
            "requests": len(self.requests),
            "tokens": toks,
            "wall_s": self.wall_s,
            "tokens_per_sec": tps,
            "tokens_per_sec_per_chip": tps / jax.device_count(),
            "ttft_ms_median": _median(ttfts) * 1e3,
            "ttft_ms_p90": _p90(ttfts) * 1e3,
            "per_token_ms_median": _median(per_tok) * 1e3,
            "decode_step_us_median": _median(self.decode_step_s) * 1e6,
            "decode_step_us_p90": _p90(self.decode_step_s) * 1e6,
            "decode_steps": self.decode_steps,
            "slot_occupancy": occ,
            "concurrent_mean": (float(np.mean(self.active_per_step))
                                if self.active_per_step else 0.0),
            "concurrent_peak": (int(max(self.active_per_step))
                                if self.active_per_step else 0),
            "rejected": sum(1 for r in self.requests.values() if r.rejected),
            "preemptions": self.preemptions,
        }
        if self.pool_samples:
            reserved = np.asarray([s[0] for s in self.pool_samples], float)
            used = np.asarray([s[1] for s in self.pool_samples], float)
            pool_blocks = self.pool_samples[-1][2]
            used_blocks = np.asarray([s[3] for s in self.pool_samples],
                                     float)
            # fragmentation: fraction of reserved cache tokens not holding
            # a live token (block-internal waste for paged, whole idle-slot
            # rows for contiguous)
            nz = reserved > 0
            out["frag_pct"] = (float(np.mean(
                (reserved[nz] - used[nz]) / reserved[nz])) * 100.0
                if nz.any() else 0.0)
            out["pool_blocks"] = pool_blocks
            out["pool_occupancy"] = (float(np.mean(used_blocks))
                                     / pool_blocks if pool_blocks else 0.0)
        return out
