"""Continuous-batching serving tier.

Layered on the functional prefill/decode factories in ``train/serve.py``:
``RequestQueue`` models the arriving workload, ``SlotManager`` maps live
requests onto a fixed decode batch (insert / evict / recycle cache rows),
``Scheduler`` interleaves prefill with batched vector-position decode, and
``ServeMetrics`` folds the event stream into TTFT / throughput numbers.
``run_oneshot`` is the static-batch baseline the benchmarks compare
against.  See docs/DESIGN.md §10.

``paged`` adds the block-granular KV allocator (``BlockPool`` /
``BlockTable`` / ``PagedSlotManager``): attention caches become a shared
pool of ``block_size``-token blocks mapped through per-request tables, so
cache memory scales with live tokens instead of worst-case reservations —
``ServeConfig(kv="paged")`` switches the scheduler over.  See
docs/DESIGN.md §12.
"""

from repro.serve.metrics import RequestRecord, ServeMetrics
from repro.serve.paged import (BlockPool, BlockTable, PagedSlotManager,
                               PoolExhausted, PreemptedSlot)
from repro.serve.queue import Request, RequestQueue
from repro.serve.scheduler import Scheduler, ServeConfig, run_oneshot
from repro.serve.slots import Slot, SlotManager

__all__ = [
    "BlockPool",
    "BlockTable",
    "PagedSlotManager",
    "PoolExhausted",
    "PreemptedSlot",
    "Request",
    "RequestQueue",
    "RequestRecord",
    "Scheduler",
    "ServeConfig",
    "ServeMetrics",
    "Slot",
    "SlotManager",
    "run_oneshot",
]
