"""Request queue for the continuous-batching serving tier.

``Request`` is one generation job (prompt tokens, budget, optional enc-dec
frames); ``RequestQueue`` holds the pending workload ordered by arrival
time and hands ready requests to the scheduler in FIFO order, with two
scheduler-facing niceties:

  * ``pop_group`` pulls up to N *equal-prompt-length* requests from the
    ready front so short prompts prefill packed in one batched call
    (padding would break the bit-parity guarantee, so only exact-length
    groups pack);
  * ``synthetic`` builds a deterministic open-loop workload — Poisson-ish
    arrivals at a given rate and a categorical prompt-length mix — so
    benchmarks and tests replay the exact same traffic.
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, List, Optional, Sequence, Tuple

import numpy as np


@dataclasses.dataclass
class Request:
    """One generation request.  ``max_new_tokens`` counts the prefill's
    first sampled token; ``frames`` feeds enc-dec / audio-frontend archs."""
    rid: int
    tokens: np.ndarray                 # (prompt_len,) int32 prompt ids
    max_new_tokens: int
    arrival: float = 0.0               # seconds since workload start
    eos_id: Optional[int] = None
    frames: Optional[np.ndarray] = None

    @property
    def prompt_len(self) -> int:
        return int(self.tokens.shape[0])


class RequestQueue:
    """Arrival-ordered pending set + FIFO ready deque."""

    def __init__(self, requests: Sequence[Request] = ()):
        self._pending: List[Request] = sorted(requests,
                                              key=lambda r: r.arrival)
        self._ready: Deque[Request] = deque()

    def __len__(self) -> int:
        return len(self._pending) + len(self._ready)

    @property
    def num_ready(self) -> int:
        return len(self._ready)

    @property
    def drained(self) -> bool:
        return not self._pending and not self._ready

    def push(self, req: Request) -> None:
        """Admit a request that is ready right now (tests / REPL use)."""
        self._ready.append(req)

    def peek(self) -> Optional[Request]:
        """Front ready request without popping it (the scheduler inspects
        length/block needs before committing to admission)."""
        return self._ready[0] if self._ready else None

    def next_arrival(self) -> Optional[float]:
        return self._pending[0].arrival if self._pending else None

    def poll(self, now: float) -> int:
        """Move requests whose arrival time has passed into the ready
        deque; returns how many arrived."""
        n = 0
        while self._pending and self._pending[0].arrival <= now:
            self._ready.append(self._pending.pop(0))
            n += 1
        return n

    def pop_group(self, max_n: int,
                  chunk_len: Optional[int] = None) -> List[Request]:
        """Pop up to ``max_n`` ready requests sharing the front request's
        prompt length (exact-length prefill packing).  Requests longer than
        ``chunk_len`` take the chunked-prefill path and always go alone."""
        if not self._ready:
            return []
        head = self._ready.popleft()
        group = [head]
        if chunk_len is not None and head.prompt_len > chunk_len:
            return group
        keep: List[Request] = []
        while self._ready and len(group) < max_n:
            r = self._ready.popleft()
            if r.prompt_len == head.prompt_len and (
                    chunk_len is None or r.prompt_len <= chunk_len):
                group.append(r)
            else:
                keep.append(r)
        self._ready.extendleft(reversed(keep))
        return group

    # ------------------------------------------------------------ workloads

    @classmethod
    def synthetic(cls, n_requests: int, vocab: int, *,
                  prompt_lens: Sequence[int] = (8, 16, 32),
                  mix: Optional[Sequence[float]] = None,
                  new_tokens: Tuple[int, int] = (4, 32),
                  budgets: Optional[Sequence[int]] = None,
                  rate: Optional[float] = None,
                  frontend_dim: Optional[int] = None,
                  seed: int = 0) -> "RequestQueue":
        """Deterministic mixed-traffic workload.

        ``rate`` (requests/sec) draws exponential inter-arrival gaps
        (open-loop Poisson process); ``rate=None`` means everything is
        already waiting at t=0.  ``mix`` weights the prompt-length
        categories.  ``budgets`` replaces the uniform ``new_tokens``
        range with a categorical draw (bimodal mixes are the workloads
        where lockstep decoding wastes the most).  ``frontend_dim`` attaches per-request frames (enc-dec
        archs; frame length == prompt length, uniform across the workload
        so the cross-attention caches align slot-for-slot).
        """
        rng = np.random.default_rng(seed)
        probs = None
        if mix is not None:
            probs = np.asarray(mix, np.float64)
            probs = probs / probs.sum()
        lens = rng.choice(np.asarray(prompt_lens), size=n_requests, p=probs)
        if budgets is not None:   # categorical budget mix (e.g. bimodal)
            budgets = rng.choice(np.asarray(budgets), size=n_requests)
        else:
            lo, hi = new_tokens
            budgets = rng.integers(lo, hi + 1, size=n_requests)
        arrivals = np.zeros(n_requests)
        if rate is not None:
            arrivals = np.cumsum(rng.exponential(1.0 / rate,
                                                 size=n_requests))
        reqs = []
        for i in range(n_requests):
            toks = rng.integers(0, vocab, size=int(lens[i])).astype(np.int32)
            frames = None
            if frontend_dim is not None:
                frames = (rng.standard_normal(
                    (int(lens[i]), frontend_dim)) * 0.1).astype(np.float32)
            reqs.append(Request(rid=i, tokens=toks,
                                max_new_tokens=int(budgets[i]),
                                arrival=float(arrivals[i]), frames=frames))
        return cls(reqs)
