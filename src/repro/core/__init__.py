"""DMuon core: the paper's contribution as a composable JAX module.

Layers:
  coefficients / newton_schulz / gram_ns — the optimizer math
  dedication / layout / load_balance     — owner planning (paper §3.1/3.2.1/3.4)
  distributed                            — owner-centric SPMD execution (§3.2/3.5)
  muon / api                             — drop-in optimizer surface (§4)
"""
