"""DMuon core: the paper's contribution as a composable JAX module.

Layers:
  coefficients / newton_schulz / gram_ns — the optimizer math
  dedication / layout / load_balance     — owner planning (paper §3.1/3.2.1/3.4)
  owner_comms                            — owner-major layout + staged
                                           all-to-all resharding (§3.2)
  orthogonalize                          — pluggable NS backends (gram,
                                           bucket-fused, NorMuon, MuonBP)
  update_rules                           — momentum/scale/wd + AdamW
  muon / api                             — orchestrator + drop-in surface
                                           with the variant registry (§4)
"""
