"""Gram-space Newton-Schulz iteration (paper §3.3, after Zhang et al.).

Write one NS step as ``X_{i+1} = P_i X_i`` with ``P_i = aI + bG_i + cG_i²``
a polynomial in the Gram matrix ``G_i = X_i X_iᵀ``.  Then the Gram matrix
obeys the closed recurrence

    G_{i+1} = P_i G_i P_i                                         (Eq. 4)

and the polar factor is recovered at the end as ``X_k = Q_k X₀`` with
``Q_{i+1} = P_i Q_i``, ``Q₀ = I``.  The iteration stays in the m×m Gram space
instead of the m×n original space, so the dominant cost falls from O(m²n) to
O(m³) whenever m < n.

Key structural fact exploited by the kernels: every matrix appearing in the
iteration (G_i, P_i, Q_i and all their products) is a *polynomial in G₀* —
they are all symmetric and they all commute.  Hence every product below has a
symmetric output and a SYRK-style kernel that computes only the lower triangle
does half the arithmetic (the paper's 48%-share "symmetric Gram kernel").

Operation schedule per step (fp32 accumulation everywhere):

    P  = aI + bG + c·(G@G)     one symmetric product + fused epilogue
    T  = P@G                   symmetric product        (skipped on last step)
    G' = P@T                   symmetric product        (skipped on last step)
    Q' = P@Q                   symmetric product        (Q' := P on first step)

giving ``4k − 3`` m×m symmetric products for k steps, plus one m×n SYRK (G₀)
and one m×n product (final ``Q_k X₀``).

The inner products dispatch either to pure-jnp reference ops or to the Pallas
TPU kernels in ``repro.kernels`` (``use_kernels=True``; CPU tests exercise the
kernels in interpret mode, the multi-pod dry-run uses the jnp path — see
docs/DESIGN.md §2 on roofline FLOP accounting).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Sequence

import jax
import jax.numpy as jnp

from repro.core.coefficients import Coeffs, get_coefficients

_EPS = 1e-7


@dataclass(frozen=True)
class GramNSConfig:
    """Execution configuration for the Gram NS iteration."""
    num_steps: int = 5
    schedule: str = "polar_express"
    compute_dtype: str = "float32"   # iterate dtype; fp32 accumulation regardless
    use_kernels: bool = False        # Pallas symmetric kernels vs pure jnp
    kernel_interpret: bool = True    # interpret mode (CPU validation) vs TPU lowering
    block_m: int = 128               # kernel block size (autotuner may override)
    block_k: int = 128
    # Owner-local batch chunking (lax.map over sub-batches): bounds the live
    # Gram-space working set for huge shape censuses (1T-class MoE configs).
    # 0 = no chunking.
    owner_chunk: int = 0
    # Fuse the m×m iteration phase across groups sharing a Gram dimension
    # (paper §3.3 shape-batched execution at its widest): one batched
    # recurrence per Gram bucket instead of one per parameter leaf.
    bucket_fusion: bool = False

    def coeffs(self) -> Sequence[Coeffs]:
        return get_coefficients(self.schedule, self.num_steps)


def _ops(cfg: GramNSConfig):
    """Resolve the (syrk, gram_poly, symmul, matmul) op set for ``cfg``."""
    if cfg.use_kernels:
        from repro.kernels import ops as kops
        kw = dict(interpret=cfg.kernel_interpret, block_m=cfg.block_m,
                  block_k=cfg.block_k)
        return (
            lambda x: kops.syrk(x, **kw),
            lambda g, a, b, c: kops.gram_poly(g, a, b, c, **kw),
            lambda a, b: kops.symmul(a, b, **kw),
        )
    from repro.kernels import ref as kref
    return kref.syrk_ref, kref.gram_poly_ref, kref.symmul_ref


def _rect_dot(a: jax.Array, b: jax.Array) -> jax.Array:
    """Batched (…, m, m) @ (…, m, n) with fp32 accumulation."""
    out = jax.lax.dot_general(
        a, b,
        dimension_numbers=(((a.ndim - 1,), (b.ndim - 2,)),
                           (tuple(range(a.ndim - 2)), tuple(range(b.ndim - 2)))),
        preferred_element_type=jnp.float32)
    return out.astype(a.dtype)


def gram_newton_schulz(
    m: jax.Array,
    cfg: GramNSConfig = GramNSConfig(),
    *,
    assume_short_fat: bool = False,
) -> jax.Array:
    """Orthogonalize ``m`` of shape ``(..., r, c)`` via Gram-space NS.

    Transposes internally so the Gram side is the smaller dimension unless
    ``assume_short_fat`` asserts r <= c already (the stacked owner-layout path
    pre-transposes groups at plan time, making the whole batch uniform).
    """
    if m.ndim < 2:
        raise ValueError(f"gram_newton_schulz expects a matrix, got {m.shape}")
    out_dtype = m.dtype
    x = m

    transposed = False
    if not assume_short_fat and m.shape[-2] > m.shape[-1]:
        x, transposed = x.mT, True

    # Frobenius norm with fp32 accumulation WITHOUT materializing an fp32
    # copy of x: the square+convert fuse into the reduction.  (An up-front
    # x.astype(f32) gets hoisted by XLA before the owner reshard, doubling
    # the transpose volume of the whole model — see docs/DESIGN.md §9.)
    norm = jnp.sqrt(jnp.sum(jnp.square(x.astype(jnp.float32)),
                            axis=(-2, -1), keepdims=True))
    cdtype = jnp.dtype(cfg.compute_dtype)
    x0 = x.astype(cdtype) / (norm + _EPS).astype(cdtype)

    syrk, gram_poly, symmul = _ops(cfg)
    coeffs = cfg.coeffs()

    g = syrk(x0)                                   # G₀ = X₀X₀ᵀ
    q: Optional[jax.Array] = None                  # Q₀ = I, kept implicit
    last = len(coeffs) - 1
    for i, (a, b, c) in enumerate(coeffs):
        p = gram_poly(g, a, b, c)                  # P = aI + bG + c(G@G)
        q = p if q is None else symmul(p, q)       # Q' = P Q
        if i != last:                              # G' not needed after last P
            t = symmul(p, g)                       # T = PG (= GP)
            g = symmul(p, t)                       # G' = PT = P G P

    out = _rect_dot(q, x0)                         # X_k = Q_k X₀
    if transposed:
        out = out.mT
    return out.astype(out_dtype)


def gram_prepare(m: jax.Array, cfg: GramNSConfig):
    """Phase 1: normalize + initial Gram.  m: (..., r, c) with r <= c.
    Returns (x0, G) — G is (..., r, r)."""
    norm = jnp.sqrt(jnp.sum(jnp.square(m.astype(jnp.float32)),
                            axis=(-2, -1), keepdims=True))
    cdtype = jnp.dtype(cfg.compute_dtype)
    x0 = m.astype(cdtype) / (norm + _EPS).astype(cdtype)
    syrk, _, _ = _ops(cfg)
    return x0, syrk(x0)


def gram_iterate(g: jax.Array, cfg: GramNSConfig) -> jax.Array:
    """Phase 2: the m×m Gram recurrence; returns the polar accumulator Q_k.
    This phase is shape-uniform in the Gram dimension only, so stacks from
    different (m, n) groups with equal m are batched together here — the
    bucket fusion of the paper's shape-batched execution."""
    _, gram_poly, symmul = _ops(cfg)
    coeffs = cfg.coeffs()
    q = None
    last = len(coeffs) - 1
    for i, (a, b, c) in enumerate(coeffs):
        p = gram_poly(g, a, b, c)
        q = p if q is None else symmul(p, q)
        if i != last:
            t = symmul(p, g)
            g = symmul(p, t)
    return q


def gram_finish(q: jax.Array, x0: jax.Array, out_dtype) -> jax.Array:
    """Phase 3: X_k = Q_k X₀."""
    return _rect_dot(q, x0).astype(out_dtype)


def gram_ns_flops(m: int, n: int, num_steps: int = 5, batch: int = 1,
                  symmetric_kernels: bool = True) -> dict:
    """Analytic FLOP model (per §Roofline kernel adjustment & load balancer).

    Returns both the naive-GEMM count (what XLA's cost_analysis sees on the
    jnp path) and the symmetric-kernel-adjusted count (what the Pallas path
    executes on TPU: every m×m product computes only the lower triangle).
    """
    if m > n:
        m, n = n, m
    sym_products = 4 * num_steps - 3
    mm = 2.0 * m * m * m                 # one full m×m×m GEMM
    rect = 2.0 * m * m * n               # one m×m @ m×n GEMM (or SYRK of X)
    full = batch * (rect                 # G₀ = X X ᵀ
                    + sym_products * mm  # Gram-space products
                    + rect)              # Q_k X₀
    half = batch * (rect / 2.0 + sym_products * mm / 2.0 + rect)
    ns_standard = batch * num_steps * (2.0 * rect + mm)
    return {
        "gram_full_gemm": full,
        "gram_symmetric_kernel": half if symmetric_kernels else full,
        "standard_ns": ns_standard,
    }
