"""Owner-layout communication machinery (the SPMD pattern of §3.2).

This module owns everything about *where matrices live*: the leaf↔matrix
reshapes, the owner-major packed stacking, and the staged resharding that
lowers the owner transpose to same-shape all-to-alls instead of XLA's
"involuntary full rematerialization" (whole-tensor all-gathers).

It deliberately knows nothing about optimization: no momentum, no
Newton-Schulz, no learning rates.  ``core/muon.py`` composes an
:class:`OwnerLayout` with an orthogonalizer (``core/orthogonalize.py``) and an
update rule (``core/update_rules.py``); tests exercise the layout in
isolation (tests/test_owner_comms.py).

Module-level functions are the stable primitive API (kept for callers that
carry an explicit ``(plan, mesh)`` pair); ``OwnerLayout`` binds them once so
optimizer code reads as ``layout.pack(key, leaves)`` / ``layout.unpack(...)``.
"""

from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.dedication import DedicationPlan

# shard_map moved from jax.experimental to the jax namespace across
# releases; resolve whichever this JAX provides once, here.
if hasattr(jax, "shard_map"):
    shard_map = jax.shard_map
else:  # pragma: no cover — depends on the installed JAX
    from jax.experimental.shard_map import shard_map


def group_key_str(key) -> str:
    """Sanitize a group key for use as a state-dict key ('/' would collide
    with the checkpoint manifest's path separator)."""
    return key.replace("/", ".") if isinstance(key, str) else \
        f"{key[0]}x{key[1]}"


def _lead_perm(info, spec) -> tuple:
    """Permutation of the leaf's leading dims putting sharded dims first
    (major).  Flattening a sharded-MAJOR axis keeps the merged-axis sharding
    expressible and every reshape local — the property that lets the owner
    transpose lower to one same-shape all-to-all instead of XLA's
    "involuntary full rematerialization" (whole-tensor all-gather)."""
    n_lead = len(info.shape) - 2
    if spec is None or n_lead <= 1:
        return tuple(range(n_lead))
    lead = list(spec)[:n_lead] if len(spec) >= n_lead else [None] * n_lead
    return tuple(sorted(range(n_lead), key=lambda i: (lead[i] is None, i)))


def _stacked_spec(info, spec):
    """Training-layout PartitionSpec of the (count, m, n) stacked view."""
    from jax.sharding import PartitionSpec as P
    if spec is None:
        return None
    n_lead = len(info.shape) - 2
    lead = list(spec)[:n_lead]
    perm = _lead_perm(info, spec)
    major = lead[perm[0]] if n_lead and perm and lead[perm[0]] is not None \
        else None
    m_spec = spec[-2] if len(spec) >= 2 else None
    n_spec = spec[-1] if len(spec) >= 1 else None
    if info.transpose:
        m_spec, n_spec = n_spec, m_spec
    return P(major, m_spec, n_spec)


def _leaf_to_matrices(arr: jax.Array, info, spec=None) -> jax.Array:
    """(lead..., m0, n0) -> (count, m, n) with m <= n, sharded-major order."""
    m0, n0 = info.shape[-2:]
    perm = _lead_perm(info, spec)
    n_lead = arr.ndim - 2
    if perm != tuple(range(n_lead)):
        arr = jnp.transpose(arr, perm + (n_lead, n_lead + 1))
    flat = arr.reshape((-1, m0, n0))
    return flat.mT if info.transpose else flat


def _matrices_to_leaf(flat: jax.Array, info, spec=None) -> jax.Array:
    if info.transpose:
        flat = flat.mT
    perm = _lead_perm(info, spec)
    n_lead = len(info.shape) - 2
    if perm != tuple(range(n_lead)):
        permuted_shape = tuple(info.shape[i] for i in perm) + info.shape[-2:]
        inv = tuple(np.argsort(perm)) + (n_lead, n_lead + 1)
        return jnp.transpose(flat.reshape(permuted_shape), inv)
    return flat.reshape(info.shape)


def pack_group(plan: DedicationPlan, key, leaf_values: Dict[str, jax.Array],
               mesh=None) -> jax.Array:
    """Stack a shape group's matrices into the owner-major padded layout.

    Output: (num_owners * capacity, m, n); position p belongs to owner
    p // capacity.  With known training specs the stacked view is explicitly
    constrained so the only communication is the same-shape axis-0
    redistribution applied afterwards by the owner constraint.
    """
    g = plan.groups[key]
    specs = getattr(plan, "train_specs", None) or {}
    parts = []
    for p in g.leaf_paths:
        spec = specs.get(p)
        part = _leaf_to_matrices(leaf_values[p], plan.leaves[p], spec)
        st_spec = _stacked_spec(plan.leaves[p], spec)
        if mesh is not None and st_spec is not None:
            from jax.sharding import NamedSharding
            part = jax.lax.with_sharding_constraint(
                part, NamedSharding(mesh, st_spec))
        parts.append(part)
    flat = parts[0] if len(parts) == 1 else jnp.concatenate(parts, axis=0)
    m, n = g.key
    n_pad = g.packed_size - g.count
    if np.array_equal(g.pack_index[:g.count], np.arange(g.count)):
        # contiguous physical layout: pure pad — partitions as a local op
        if n_pad == 0:
            return flat
        return jnp.concatenate(
            [flat, jnp.zeros((n_pad, m, n), flat.dtype)], axis=0)
    pad = jnp.zeros((1, m, n), flat.dtype)
    flat_ext = jnp.concatenate([flat, pad], axis=0)
    idx = np.where(g.pack_index < 0, g.count, g.pack_index)
    return jnp.take(flat_ext, jnp.asarray(idx), axis=0)


def unpack_group(plan: DedicationPlan, key, packed: jax.Array,
                 mesh=None) -> Dict[str, jax.Array]:
    """Inverse of pack_group: owner-major stack -> per-leaf arrays.

    The publish reshard (owner layout -> training layout) happens HERE at the
    padded stacked shape — a same-shape axis redistribution (all-to-all) —
    before any slice/transpose/reshape, all of which are then sharding-local.
    """
    g = plan.groups[key]
    specs = getattr(plan, "train_specs", None) or {}
    if len(g.leaf_paths) == 1 and mesh is not None:
        p = g.leaf_paths[0]
        st_spec = _stacked_spec(plan.leaves[p], specs.get(p))
        if st_spec is not None:
            packed = _from_owner_staged(packed, st_spec, plan, mesh)
    if np.array_equal(g.unpack_index, np.arange(g.count)):
        flat = packed[:g.count]            # contiguous layout: pure slice
    else:
        flat = jnp.take(packed, jnp.asarray(g.unpack_index), axis=0)
    out: Dict[str, jax.Array] = {}
    start = 0
    for p in g.leaf_paths:
        info = plan.leaves[p]
        out[p] = _matrices_to_leaf(flat[start:start + info.count], info,
                                   specs.get(p))
        start += info.count
    return out


def owner_sharding(plan: DedicationPlan, mesh, ndim: int = 3):
    """NamedSharding for owner-major state buffers: axis 0 over the owner
    mesh axes, trailing ``ndim - 1`` dims replicated.  ``ndim=3`` covers the
    (D·cap, m, n) momentum stacks; variant state may carry (D·cap, m)
    buffers (e.g. NorMuon's neuron-wise second moments) with ``ndim=2``."""
    if mesh is None:
        return None
    from jax.sharding import NamedSharding, PartitionSpec as P
    axes = plan.owner_axes or tuple(mesh.axis_names)
    return NamedSharding(mesh, P(axes, *([None] * (ndim - 1))))


def _constrain(x, sharding):
    if sharding is None:
        return x
    return jax.lax.with_sharding_constraint(x, sharding)


def _to_owner_staged(x, stacked_spec, plan, mesh):
    """Training-stacked layout -> owner layout, one mesh axis per stage.

    Each stage moves a single mesh axis from a matrix dim onto the stack
    axis — a reshard GSPMD lowers as a true all-to-all.  Jumping directly to
    the owner spec lets XLA resolve the two-axis move "through replication"
    (full-tensor all-gathers), a TB-scale temp at 340B+ scale; see
    docs/DESIGN.md §2 and §9 (nemotron train iteration).
    """
    if mesh is None:
        return x
    from jax.sharding import NamedSharding, PartitionSpec as P
    axes = plan.owner_axes or tuple(mesh.axis_names)
    cur = list(stacked_spec) if stacked_spec is not None else [None] * 3
    while len(cur) < 3:
        cur.append(None)
    front = list(cur[0]) if isinstance(cur[0], tuple) else \
        ([cur[0]] if cur[0] is not None else [])
    for ax in axes:
        if ax in front:
            continue
        rest = [None if d == ax else d for d in cur[1:]]
        front = front + [ax]
        cur = [tuple(front)] + rest
        x = jax.lax.with_sharding_constraint(
            x, NamedSharding(mesh, P(*cur)))
    return x


def _from_owner_staged(x, stacked_spec, plan, mesh):
    """Owner layout -> training-stacked layout (publish), staged in reverse:
    one axis leaves the stack dim per stage (an all-to-all back to its matrix
    dim, or an all-gather when the training layout doesn't use it)."""
    if mesh is None:
        return x
    from jax.sharding import NamedSharding, PartitionSpec as P
    axes = list(plan.owner_axes or tuple(mesh.axis_names))
    target = list(stacked_spec) if stacked_spec is not None else [None] * 3
    while len(target) < 3:
        target.append(None)
    front = list(axes)
    rest = [None, None]
    for ax in reversed(axes):
        front = [a for a in front if a != ax]
        for di in (1, 2):
            if target[di] == ax:
                rest[di - 1] = ax
        lead = tuple(front) if front else target[0]
        x = jax.lax.with_sharding_constraint(
            x, NamedSharding(mesh, P(lead, rest[0], rest[1])))
    return x


def repack_rows(old_g, new_g, buf: jax.Array) -> jax.Array:
    """Re-layout one owner-major buffer across plans: unpack the logical rows
    under ``old_g`` (a GroupPlan), repack/pad under ``new_g``.  Works on any
    (packed_size, ...) buffer — momentum stacks, NorMuon (D·cap, m) moments,
    MuonBP (D·cap, m, m) caches — so elastic restart reshards every piece of
    owner state with the same code path."""
    if np.array_equal(old_g.unpack_index, np.arange(old_g.count)):
        rows = buf[:old_g.count]
    else:
        rows = jnp.take(buf, jnp.asarray(old_g.unpack_index), axis=0)
    n_pad = new_g.packed_size - new_g.count
    if np.array_equal(new_g.pack_index[:new_g.count],
                      np.arange(new_g.count)):
        if n_pad == 0:
            return rows
        return jnp.concatenate(
            [rows, jnp.zeros((n_pad,) + rows.shape[1:], rows.dtype)], 0)
    ext = jnp.concatenate(
        [rows, jnp.zeros((1,) + rows.shape[1:], rows.dtype)], 0)
    idx = np.where(new_g.pack_index < 0, new_g.count, new_g.pack_index)
    return jnp.take(ext, jnp.asarray(idx), axis=0)


class OwnerLayout:
    """The pack/reshard half of the optimizer, bound to a (plan, mesh) pair.

    One instance per optimizer; all methods are pure and jit-traceable.  The
    optimizer core never touches PartitionSpecs directly — it asks the layout
    to move tensors between the training layout and the owner layout.
    """

    def __init__(self, plan: DedicationPlan, mesh=None):
        self.plan = plan
        self.mesh = mesh
        self.sharding = owner_sharding(plan, mesh)

    # ---------------------------------------------------------- structure

    @property
    def group_keys(self):
        return list(self.plan.groups.keys())

    def packed_shape(self, key) -> tuple:
        g = self.plan.groups[key]
        return (g.packed_size,) + g.key

    def buffer_sharding(self, ndim: int = 3):
        """Sharding for an owner-major state buffer of rank ``ndim``."""
        return owner_sharding(self.plan, self.mesh, ndim)

    def zeros(self, key, dtype, trailing: tuple = None) -> jax.Array:
        """Owner-sharded zero state buffer for group ``key``.  ``trailing``
        overrides the per-row shape (default: the (m, n) matrix)."""
        g = self.plan.groups[key]
        shape = (g.packed_size,) + (g.key if trailing is None
                                    else tuple(trailing))
        buf = jnp.zeros(shape, dtype)
        return _constrain(buf, self.buffer_sharding(len(shape)))

    # -------------------------------------------------------- movement

    def stacked_spec(self, key):
        """Training-layout spec of the stacked view (single-leaf groups)."""
        g = self.plan.groups[key]
        if len(g.leaf_paths) != 1:
            return None
        p = g.leaf_paths[0]
        specs = getattr(self.plan, "train_specs", None) or {}
        return _stacked_spec(self.plan.leaves[p], specs.get(p))

    def pack(self, key, leaf_values: Dict[str, jax.Array]) -> jax.Array:
        """Training layout -> owner-major stack (reduce-to-owner direction):
        stack + stage the all-to-alls + pin the owner sharding."""
        packed = pack_group(self.plan, key, leaf_values, mesh=self.mesh)
        packed = _to_owner_staged(packed, self.stacked_spec(key), self.plan,
                                  self.mesh)
        return _constrain(packed, self.sharding)

    def unpack(self, key, packed: jax.Array) -> Dict[str, jax.Array]:
        """Owner-major stack -> training layout (publish direction)."""
        return unpack_group(self.plan, key, packed, mesh=self.mesh)

    def constrain(self, x: jax.Array) -> jax.Array:
        """Pin ``x`` (an owner-major stack) to the owner sharding."""
        return _constrain(x, self.sharding)

    def constrain_buffer(self, x: jax.Array) -> jax.Array:
        """Pin an owner-major state buffer of any rank (axis 0 = stack)."""
        return _constrain(x, self.buffer_sharding(x.ndim))

    # ---------------------------------------------------------- local map

    def shard_local(self, fn, tree_in):
        """Run ``fn`` over owner-sharded stacks with provably local compute.

        ``tree_in`` is a (nested) dict of owner-major buffers; under a mesh
        the call is wrapped in shard_map with the stack axis sharded over the
        owner axes (no collectives inside — each device handles its own
        matrices); without one, ``fn`` runs directly (unit tests).
        shard_map infers the per-leaf specs from leaf ranks.
        """
        if self.mesh is None:
            return fn(tree_in)
        from jax.sharding import PartitionSpec as P
        axes = self.plan.owner_axes or tuple(self.mesh.axis_names)

        def spec_of(leaf):
            return P(axes, *([None] * (leaf.ndim - 1)))
        in_specs = jax.tree.map(spec_of, tree_in)
        out_shape = jax.eval_shape(fn, tree_in)
        out_specs = jax.tree.map(spec_of, out_shape)
        return shard_map(fn, mesh=self.mesh, in_specs=(in_specs,),
                         out_specs=out_specs)(tree_in)
