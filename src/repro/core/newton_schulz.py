"""Standard (non-Gram) Newton-Schulz orthogonalization.

This is the compute path of the gather-then-compute baseline (``Muon-AG`` in
the paper): every rank materializes the full momentum matrix and runs the
iteration below.  It is also the semantic oracle for the Gram-space path in
``gram_ns.py`` — the two must agree to within iteration-reordering rounding.

The iteration approximates the matrix sign / polar factor ``UVᵀ`` of the
SVD ``M = UΣVᵀ``:

    X₀ = M / ||M||_F
    X_{i+1} = a X_i + (b A_i + c A_i²) X_i,   A_i = X_i X_iᵀ          (Eq. 2)

All matmuls accumulate in fp32 (``preferred_element_type``) regardless of the
working dtype; on TPU the working dtype is bf16 by default (see docs/DESIGN.md §2
for the fp16→bf16 adaptation note).
"""

from __future__ import annotations

from typing import Optional, Sequence

import jax
import jax.numpy as jnp

from repro.core.coefficients import Coeffs, get_coefficients

_EPS = 1e-7


def _dot(a: jax.Array, b: jax.Array) -> jax.Array:
    """Batched matmul over the last two dims with fp32 accumulation."""
    return jax.lax.dot_general(
        a, b,
        dimension_numbers=(((a.ndim - 1,), (b.ndim - 2,)),
                           (tuple(range(a.ndim - 2)), tuple(range(b.ndim - 2)))),
        preferred_element_type=jnp.float32,
    ).astype(a.dtype)


def newton_schulz(
    m: jax.Array,
    *,
    num_steps: int = 5,
    schedule: str | Sequence[Coeffs] = "polar_express",
    compute_dtype: Optional[jnp.dtype] = None,
) -> jax.Array:
    """Orthogonalize ``m`` (shape ``(..., r, c)``) via standard Newton-Schulz.

    Handles tall matrices by transposing so the iteration runs on the smaller
    Gram side, exactly as reference Muon implementations do.  Returns an array
    of the same shape and dtype as ``m``.
    """
    if m.ndim < 2:
        raise ValueError(f"newton_schulz expects a matrix, got shape {m.shape}")
    coeffs = (get_coefficients(schedule, num_steps)
              if isinstance(schedule, str) else tuple(schedule)[:num_steps])

    out_dtype = m.dtype
    cdtype = compute_dtype or jnp.float32
    x = m.astype(jnp.float32)

    transposed = m.shape[-2] > m.shape[-1]
    if transposed:
        x = x.mT

    norm = jnp.linalg.norm(x, axis=(-2, -1), keepdims=True)
    x = (x / (norm + _EPS)).astype(cdtype)

    for a, b, c in coeffs:
        g = _dot(x, x.mT)                      # A = X Xᵀ      (r² c flops)
        poly = b * g + c * _dot(g, g)          # bA + cA²      (r³)
        x = a * x + _dot(poly, x)              # aX + (·)X     (r² c)

    if transposed:
        x = x.mT
    return x.astype(out_dtype)


def msign_svd(m: jax.Array) -> jax.Array:
    """Exact polar factor UVᵀ via SVD — test oracle only (not used in training)."""
    u, _, vt = jnp.linalg.svd(m.astype(jnp.float32), full_matrices=False)
    return (u @ vt).astype(m.dtype)
