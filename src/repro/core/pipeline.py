"""Bucket-pipelined optimizer schedule: stage_in → compute → publish.

The fused owner path (``core/muon.py:_owner_update``) runs the optimizer as
one post-backward phase: pack EVERY group, orthogonalize EVERY group, publish
EVERY group.  Nothing in that program tells XLA's latency-hiding scheduler
that group k+1's staged all-to-all could fly while group k's Gram recurrence
occupies the MXU — and at the memory level all staging buffers are live at
once.

This module decomposes the step into an explicitly schedulable pipeline over
*Gram buckets* (``plan.buckets``: groups sharing a Gram dimension m — the
granularity at which the iterate phase fuses, docs/DESIGN.md §6):

  ``stage_in(b)``  pack the bucket's gradients + staged all-to-all to owners
  ``compute(b)``   momentum + the variant's orthogonalizer on the local slice
  ``publish(b)``   staged reshard back to the training layout + scale/wd/lr

and software-pipelines them with double-buffered staging:

    stage_in(b₀) → [stage_in(b₁) ‖ compute(b₀)]
                 → [stage_in(b₂) ‖ compute(b₁) ‖ publish(b₀)] → …

The schedule is enforced with ``lax.optimization_barrier`` ties: bucket k+1's
staging buffers are grouped with bucket k's compute output, so the partitioner
can neither hoist every all-to-all to the front (unbounded staging memory) nor
sink them all to the back (zero overlap) — at most one staging buffer is in
flight ahead of the compute wavefront.

Gradients can also arrive *pre-staged*: with gradient accumulation,
``train/step.py`` packs each microbatch's matrix gradients into the owner
layout inside the ``lax.scan`` and accumulates there, so the owner transposes
ride under the next microbatch's forward/backward instead of forming one
post-backward barrier.  ``run_staged`` then starts the pipeline at
``compute``.  Pack is a (linear) permutation + zero-pad, so accumulating
packed microbatch gradients is bit-exact with packing the accumulated
gradient — ``tests/test_pipeline.py`` pins this down for every registry
variant.

All four registry variants (muon / normuon / muonbp / adamw) ride the
pipeline unchanged: the orthogonalizer protocol already takes a dict of
stacks, so each bucket's compute is one backend call on the bucket's slice of
``MuonState.variant_state`` (sliced/merged per field by ``_slice_state`` /
``_merge_state`` — the same {field: {group: buffer}} shape the elastic
resharder walks).
"""

from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.dedication import DedicationPlan
from repro.core.orthogonalize import make_orthogonalizer
from repro.core.owner_comms import OwnerLayout, group_key_str, repack_rows
from repro.core.update_rules import (apply_wd_and_lr, momentum_update,
                                     scale_factor)


def _tie(*trees):
    """Group ``trees`` into one scheduling unit (identity semantics).

    Consumers of any returned leaf wait for every input leaf, which is how
    the bucket schedule expresses "stage_in(k+1) completes alongside
    compute(k)" to XLA's scheduler without changing a single value.
    """
    flat, defs, sizes = [], [], []
    for t in trees:
        leaves, tdef = jax.tree_util.tree_flatten(t)
        flat.extend(leaves)
        defs.append(tdef)
        sizes.append(len(leaves))
    out = jax.lax.optimization_barrier(tuple(flat))
    res, off = [], 0
    for tdef, n in zip(defs, sizes):
        res.append(jax.tree_util.tree_unflatten(tdef, list(out[off:off + n])))
        off += n
    return tuple(res)


def _after(tree, dep):
    """Return ``tree`` unchanged but data-dependent on ``dep``: producers of
    the returned leaves cannot be scheduled before ``dep`` is computed."""
    if dep is None:
        return tree
    out, _ = _tie(tree, dep)
    return out


def _slice_state(state: Optional[dict], skeys: List[str]) -> Optional[dict]:
    """Per-bucket view of a variant state ({field: {skey: buf} | other})."""
    if state is None:
        return None
    return {field: ({k: bufs[k] for k in skeys if k in bufs}
                    if isinstance(bufs, dict) else bufs)
            for field, bufs in state.items()}


def _merge_state(acc: Optional[dict], part: Optional[dict]) -> Optional[dict]:
    """Fold one bucket's updated state slice back into the full state."""
    if part is None:
        return acc
    if acc is None:
        acc = {}
    for field, bufs in part.items():
        if isinstance(bufs, dict):
            acc.setdefault(field, {}).update(bufs)
        else:
            acc[field] = bufs
    return acc


def reshard_staged(staged: Dict[str, jax.Array], old_plan: DedicationPlan,
                   new_plan: DedicationPlan, new_mesh=None
                   ) -> Dict[str, jax.Array]:
    """Re-layout in-flight staged gradient stacks across dedication plans.

    A preemption mid-accumulation leaves owner-major staging buffers (partial
    gradient sums) that, like every owner buffer, are padded to the OLD plan's
    ``D·cap`` rows.  This repacks their logical rows under the new plan so an
    elastic restart can finish the interrupted step at a different owner
    count (tests/test_pipeline.py::test_staged_state_elastic_reshard).
    """
    from repro.core.owner_comms import owner_sharding
    skey_to_key = {group_key_str(k): k for k in old_plan.groups}
    out = {}
    for skey, buf in staged.items():
        key = skey_to_key[skey]
        packed = repack_rows(old_plan.groups[key], new_plan.groups[key], buf)
        shard = owner_sharding(new_plan, new_mesh, ndim=packed.ndim)
        if shard is not None:
            packed = jax.device_put(packed, shard)
        out[skey] = packed
    return out


class BucketPipeline:
    """The schedulable per-bucket realization of the owner update.

    One instance per (plan, config, mesh) triple; every method is pure and
    jit-traceable.  ``run_from_grads`` is the drop-in replacement for the
    fused ``_owner_update`` body; ``stage_in`` + ``run_staged`` split the
    step around the backward pass for the accumulation-overlapped mode.
    """

    def __init__(self, plan: DedicationPlan, cfg, mesh=None, spec=None):
        if spec is None:
            from repro.core.api import get_variant
            spec = get_variant(cfg.variant)
        self.plan = plan
        self.cfg = cfg
        self.mesh = mesh
        self.spec = spec
        self.layout = OwnerLayout(plan, mesh)
        self.ortho = make_orthogonalizer(spec.orthogonalizer, cfg)
        # Schedule order: Gram buckets, largest m first — the longest compute
        # goes first so later (cheaper) buckets have the most staging overlap
        # to hide behind.  Values are unaffected (buckets are independent).
        self.schedule: List[Tuple[int, List[Any]]] = sorted(
            plan.buckets.items(), key=lambda kv: -kv[0])
        # Schedule ties only pay for themselves when there are owner
        # transfers to overlap; on a single device they just fence XLA's
        # fusion.  Identity semantics either way — values are unaffected.
        multi = mesh is not None and mesh.devices.size > 1
        self.barriers = bool(getattr(cfg, "pipeline_barriers", True)) and multi

    # ------------------------------------------------------------ stages

    def stage_in(self, keys, grads: Dict[str, jax.Array], *,
                 dtype=None) -> Dict[str, jax.Array]:
        """Pack one bucket's gradients and issue the staged all-to-all to the
        owner layout.  ``dtype`` casts the leaves before packing (pack_dtype
        on the direct path; the accumulator dtype when pre-staging)."""
        out = {}
        for key in keys:
            g = self.plan.groups[key]
            leaves = {p: (grads[p] if dtype is None
                          else grads[p].astype(dtype))
                      for p in g.leaf_paths}
            out[group_key_str(key)] = self.layout.pack(key, leaves)
        return out

    def stage_in_all(self, grads: Dict[str, jax.Array], *,
                     dtype=None) -> Dict[str, jax.Array]:
        """stage_in over every bucket (the pre-staging path inside the
        microbatch scan, where the schedule is the scan itself)."""
        out = {}
        for _, keys in self.schedule:
            out.update(self.stage_in(keys, grads, dtype=dtype))
        return out

    def zeros_staged(self, dtype) -> Dict[str, jax.Array]:
        """Owner-sharded zero staging accumulators for every group."""
        return {group_key_str(k): self.layout.zeros(k, dtype)
                for k in self.plan.groups}

    def compute(self, keys, staged: Dict[str, jax.Array], momentum, step,
                vstate):
        """Momentum + the variant's orthogonalizer for one bucket, on the
        owner-local slice only."""
        cfg = self.cfg
        pdt = jnp.dtype(cfg.pack_dtype)
        mdt = jnp.dtype(cfg.momentum_dtype)
        new_mom, eff = {}, {}
        for key in keys:
            skey = group_key_str(key)
            mom = momentum[skey].astype(pdt)
            mom, e = momentum_update(mom, staged[skey].astype(pdt), cfg)
            new_mom[skey] = self.layout.constrain(mom.astype(mdt))
            eff[skey] = self.layout.constrain(e)
        skeys = [group_key_str(k) for k in keys]
        ortho, new_sub = self.ortho(eff, step=step,
                                    state=_slice_state(vstate, skeys),
                                    layout=self.layout, cfg=cfg)
        return ortho, new_mom, new_sub

    def publish(self, keys, ortho: Dict[str, jax.Array], params_matrix):
        """Staged reshard back to the training layout + scale / wd / lr."""
        cfg = self.cfg
        pdt = jnp.dtype(cfg.pack_dtype)
        updates = {}
        for key in keys:
            skey = group_key_str(key)
            m, n = self.plan.groups[key].key
            s = scale_factor(m, n, cfg.scale_mode)
            per_leaf = self.layout.unpack(key, ortho[skey].astype(pdt) * s)
            for p, upd in per_leaf.items():
                updates[p] = apply_wd_and_lr(upd, params_matrix[p], cfg)
        return updates

    # ---------------------------------------------------------- schedules

    def run_from_grads(self, gm, pm, state):
        """Full pipelined step from training-layout gradients.

        Drop-in for the fused owner update: same math per group, but staged
        per bucket with the double-buffered schedule.  Returns
        ``(matrix_updates, new_momentum, new_error_feedback, new_vstate)``.
        """
        from repro.core.muon import compress_with_error_feedback
        grads_for_pack, new_ef = compress_with_error_feedback(
            gm, state.error_feedback, self.cfg)
        pdt = jnp.dtype(self.cfg.pack_dtype)

        sched = self.schedule
        n = len(sched)
        matrix_updates: Dict[str, jax.Array] = {}
        new_momentum: Dict[str, jax.Array] = {}
        new_vstate: Optional[dict] = None
        cur = self.stage_in(sched[0][1], grads_for_pack, dtype=pdt) \
            if n else {}
        prev_ortho = None
        for i, (_, keys) in enumerate(sched):
            nxt = None
            if i + 1 < n:
                # Issue bucket i+1's staging while bucket i computes; the
                # _after tie keeps it from launching before bucket i-1's
                # compute retired (double buffering, not all-at-once).
                nxt = self.stage_in(
                    sched[i + 1][1],
                    _after(
                        {p: grads_for_pack[p]
                         for k in sched[i + 1][1]
                         for p in self.plan.groups[k].leaf_paths},
                        prev_ortho) if self.barriers else grads_for_pack,
                    dtype=pdt)
            ortho, mom_b, vs_b = self.compute(keys, cur, state.momentum,
                                              state.step, state.variant_state)
            if self.barriers and nxt is not None:
                nxt, ortho = _tie(nxt, ortho)
            matrix_updates.update(self.publish(keys, ortho, pm))
            new_momentum.update(mom_b)
            new_vstate = _merge_state(new_vstate, vs_b)
            prev_ortho = ortho
            cur = nxt
        return matrix_updates, new_momentum, new_ef, new_vstate

    def run_staged(self, staged: Dict[str, jax.Array], pm, state):
        """Compute + publish pipeline over pre-staged owner-layout gradients
        (stage_in already happened inside the microbatch scan).  Returns
        ``(matrix_updates, new_momentum, new_vstate)``."""
        matrix_updates: Dict[str, jax.Array] = {}
        new_momentum: Dict[str, jax.Array] = {}
        new_vstate: Optional[dict] = None
        prev_ortho = None
        for _, keys in self.schedule:
            bucket_staged = {group_key_str(k): staged[group_key_str(k)]
                             for k in keys}
            if self.barriers and prev_ortho is not None:
                # publish(k-1) rides alongside compute(k)
                bucket_staged = _after(bucket_staged, prev_ortho)
            ortho, mom_b, vs_b = self.compute(keys, bucket_staged,
                                              state.momentum, state.step,
                                              state.variant_state)
            matrix_updates.update(self.publish(keys, ortho, pm))
            new_momentum.update(mom_b)
            new_vstate = _merge_state(new_vstate, vs_b)
            prev_ortho = ortho
        return matrix_updates, new_momentum, new_vstate
