"""Drop-in user surface (paper Fig. 1a):

    import repro.core.api as dmuon
    plan = dmuon.dedicate_params(params, mesh=mesh)
    opt  = dmuon.Muon(plan, learning_rate=0.02)
    state = opt.init(params)
    updates, state = opt.update(grads, state, params)

The optimizer follows the optax GradientTransformation protocol (init/update
returning update *deltas*), so it composes with any JAX training loop without
framework-level modifications — the drop-in property the paper claims for the
PyTorch optimizer protocol, transplanted to the JAX convention.  State-dict
accessors round-trip through the checkpoint manager (repro.checkpoint).
"""

from __future__ import annotations

from dataclasses import replace
from typing import NamedTuple, Optional, Tuple

import numpy as np

from repro.core import dedication
from repro.core.dedication import DedicationPlan, default_muon_predicate
from repro.core.gram_ns import GramNSConfig
from repro.core.muon import (MuonConfig, MuonState, muon_init, muon_update)

__all__ = ["dedicate_params", "Muon", "MuonConfig", "GramNSConfig",
           "DedicationPlan", "default_muon_predicate"]


def dedicate_params(params, mesh=None, *, num_owners: Optional[int] = None,
                    strategy: str = "load_balance",
                    owner_axes: Tuple[str, ...] = (), **kw) -> DedicationPlan:
    """Plan ownership for ``params`` over ``mesh`` (or ``num_owners`` slots).

    With a mesh, the owner axis is the flattened mesh (all axes by default;
    restrict with ``owner_axes``) and the XOR slot layout uses the two
    outermost axes as (rows, cols).
    """
    if mesh is not None:
        axes = owner_axes or tuple(mesh.axis_names)
        sizes = [mesh.shape[a] for a in axes]
        num_owners = int(np.prod(sizes))
        cols = sizes[-1]
        rows = num_owners // cols
        kw.setdefault("mesh_rows", rows)
        kw.setdefault("mesh_cols", cols)
    elif num_owners is None:
        num_owners = 1
    return dedication.dedicate_params(
        params, num_owners=num_owners, strategy=strategy,
        owner_axes=owner_axes, **kw)


class Muon:
    """Optax-style optimizer implementing the DMuon training step (Alg. 1)."""

    def __init__(self, plan: DedicationPlan, mesh=None,
                 config: Optional[MuonConfig] = None, **overrides):
        self.plan = plan
        self.mesh = mesh
        cfg = config or MuonConfig()
        if overrides:
            cfg = replace(cfg, **overrides)
        self.config = cfg

    def init(self, params) -> MuonState:
        return muon_init(self.plan, params, self.config, self.mesh)

    def update(self, grads, state: MuonState, params):
        return muon_update(self.plan, grads, state, params, self.config,
                           self.mesh)

    # state-dict accessors (paper §4: "the state-dict accessors")
    def state_dict(self, state: MuonState) -> dict:
        return {"step": state.step, "momentum": state.momentum,
                "adamw_mu": state.adamw.mu, "adamw_nu": state.adamw.nu,
                "error_feedback": state.error_feedback}

    def load_state_dict(self, d: dict) -> MuonState:
        from repro.core.muon import AdamWState
        return MuonState(step=d["step"], momentum=d["momentum"],
                         adamw=AdamWState(d["adamw_mu"], d["adamw_nu"]),
                         error_feedback=d.get("error_feedback"))


def reshard_owner_state(state, old_plan: DedicationPlan,
                        new_plan: DedicationPlan, new_mesh=None):
    """Elastic restart across owner counts (fault-tolerance substrate).

    Owner-layout momentum buffers are padded to ``D·cap`` rows, so a
    checkpoint taken at D owners cannot be loaded verbatim onto D′ owners
    after a node failure.  This unpacks each group's momentum to its logical
    (count, m, n) rows under the OLD plan and repacks/pads it under the NEW
    plan — semantics are exactly preserved (the pad rows are zeros and never
    consumed).  AdamW moments and error feedback are training-layout pytrees
    and reshard by placement alone.
    """
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core.muon import (MuonState, _group_key_str, owner_sharding)

    new_momentum = {}
    shard = owner_sharding(new_plan, new_mesh)
    for key, old_g in old_plan.groups.items():
        new_g = new_plan.groups[key]
        assert old_g.count == new_g.count, (key, old_g.count, new_g.count)
        buf = state.momentum[_group_key_str(key)]
        # unpack logical rows under the old plan
        if np.array_equal(old_g.unpack_index, np.arange(old_g.count)):
            rows = buf[:old_g.count]
        else:
            rows = jnp.take(buf, jnp.asarray(old_g.unpack_index), axis=0)
        # repack under the new plan
        n_pad = new_g.packed_size - new_g.count
        if np.array_equal(new_g.pack_index[:new_g.count],
                          np.arange(new_g.count)):
            packed = rows if n_pad == 0 else jnp.concatenate(
                [rows, jnp.zeros((n_pad,) + rows.shape[1:], rows.dtype)], 0)
        else:
            ext = jnp.concatenate(
                [rows, jnp.zeros((1,) + rows.shape[1:], rows.dtype)], 0)
            idx = np.where(new_g.pack_index < 0, new_g.count,
                           new_g.pack_index)
            packed = jnp.take(ext, jnp.asarray(idx), axis=0)
        if shard is not None:
            packed = jax.device_put(packed, shard)
        new_momentum[_group_key_str(key)] = packed
    return MuonState(step=state.step, momentum=new_momentum,
                     adamw=state.adamw,
                     error_feedback=state.error_feedback)
