"""Drop-in user surface (paper Fig. 1a):

    import repro.core.api as dmuon
    plan = dmuon.dedicate_params(params, mesh=mesh)
    opt  = dmuon.Muon(plan, learning_rate=0.02)
    state = opt.init(params)
    updates, state = opt.update(grads, state, params)

The optimizer follows the optax GradientTransformation protocol (init/update
returning update *deltas*), so it composes with any JAX training loop without
framework-level modifications — the drop-in property the paper claims for the
PyTorch optimizer protocol, transplanted to the JAX convention.  State-dict
accessors round-trip through the checkpoint manager (repro.checkpoint).

Variant registry
----------------
``MuonConfig.variant`` selects a named optimizer variant; all variants share
the owner-layout pipeline (core/owner_comms.py) and differ only in the
orthogonalizer backend (core/orthogonalize.py) + its per-group state:

    muon     — plain orthogonalized updates (the paper's optimizer)
    normuon  — NorMuon (arXiv:2510.05491): neuron-wise second-moment
               normalization of the orthogonalized update
    muonbp   — MuonBP (arXiv:2510.16981): full NS refresh every
               ``muonbp_period`` steps, cached polar map in between
    dion2    — Dion2 (arXiv:2512.16928): Gram NS on a warm-started rank-r
               factor only (``dion2_rank_frac``), full update reconstructed
    adamuon  — AdaMuon (arXiv:2507.11005): elementwise second-moment
               adaptation of the orthogonalized update, norm-preserving
    adamw    — elementwise AdamW baseline

``register_variant`` lets downstream scenarios plug in further backends
without touching the pipeline.
"""

from __future__ import annotations

from dataclasses import replace
from typing import Dict, Optional, Tuple

import numpy as np

from repro.core import dedication
from repro.core.dedication import DedicationPlan, default_muon_predicate
from repro.core.gram_ns import GramNSConfig
from repro.core.muon import (MuonConfig, MuonState, muon_init, muon_update)
from repro.core.update_rules import VariantSpec

__all__ = ["dedicate_params", "Muon", "MuonConfig", "GramNSConfig",
           "DedicationPlan", "default_muon_predicate", "VariantSpec",
           "VARIANTS", "register_variant", "get_variant",
           "reshard_owner_state"]


# --------------------------------------------------------------------------
# Variant registry
# --------------------------------------------------------------------------

VARIANTS: Dict[str, VariantSpec] = {}


def register_variant(spec: VariantSpec, *, overwrite: bool = False) -> None:
    """Register a named optimizer variant (e.g. from a scenario plugin)."""
    if spec.name in VARIANTS and not overwrite:
        raise ValueError(f"variant {spec.name!r} already registered")
    VARIANTS[spec.name] = spec


def get_variant(name: str) -> VariantSpec:
    try:
        return VARIANTS[name]
    except KeyError:
        raise ValueError(
            f"unknown variant {name!r}; known: {sorted(VARIANTS)}") from None


register_variant(VariantSpec(
    "muon", orthogonalizer="auto",
    description="owner-centric Muon: batched Gram NS (bucket-fused when "
                "GramNSConfig.bucket_fusion)"))
register_variant(VariantSpec(
    "normuon", orthogonalizer="normuon", stateful=True,
    description="Muon + NorMuon neuron-wise second-moment normalization"))
register_variant(VariantSpec(
    "muonbp", orthogonalizer="block_periodic", stateful=True,
    description="Muon with block-periodic NS refresh (MuonBP)"))
register_variant(VariantSpec(
    "dion2", orthogonalizer="dion2", stateful=True,
    description="Dion2: batched Gram NS on a warm-started rank-r factor "
                "only (dion2_rank_frac), full update reconstructed"))
register_variant(VariantSpec(
    "adamuon", orthogonalizer="adamuon", stateful=True,
    description="AdaMuon: elementwise second-moment adaptation of the "
                "orthogonalized update, norm-preserving"))
register_variant(VariantSpec(
    "adamw", orthogonalizer="none", elementwise=True,
    description="elementwise AdamW baseline (no matrix pipeline)"))


def dedicate_params(params, mesh=None, *, num_owners: Optional[int] = None,
                    strategy: str = "load_balance",
                    owner_axes: Tuple[str, ...] = (), **kw) -> DedicationPlan:
    """Plan ownership for ``params`` over ``mesh`` (or ``num_owners`` slots).

    With a mesh, the owner axis is the flattened mesh (all axes by default;
    restrict with ``owner_axes``) and the XOR slot layout uses the two
    outermost axes as (rows, cols).
    """
    if mesh is not None:
        axes = owner_axes or tuple(mesh.axis_names)
        sizes = [mesh.shape[a] for a in axes]
        num_owners = int(np.prod(sizes))
        cols = sizes[-1]
        rows = num_owners // cols
        kw.setdefault("mesh_rows", rows)
        kw.setdefault("mesh_cols", cols)
    elif num_owners is None:
        num_owners = 1
    return dedication.dedicate_params(
        params, num_owners=num_owners, strategy=strategy,
        owner_axes=owner_axes, **kw)


class Muon:
    """Optax-style optimizer implementing the DMuon training step (Alg. 1)."""

    def __init__(self, plan: DedicationPlan, mesh=None,
                 config: Optional[MuonConfig] = None, **overrides):
        self.plan = plan
        self.mesh = mesh
        cfg = config or MuonConfig()
        if overrides:
            cfg = replace(cfg, **overrides)
        spec = get_variant(cfg.variant)   # fail fast on unknown variants
        self.config = cfg
        if cfg.autotune_prewarm and not spec.elementwise:
            # Paper §3.3 workflow: parameter shapes are fixed for the whole
            # run, so tune (or analytically score) every kernel shape the
            # dedication plan can launch once, at init, into the persistent
            # cache — the hot path then always hits.
            from repro.kernels.autotune import prewarm_plan
            prewarm_plan(plan, dtypes=(cfg.ns.compute_dtype,))

    @property
    def variant(self) -> VariantSpec:
        return get_variant(self.config.variant)

    @property
    def effective_mode(self) -> str:
        """Execution mode after variant resolution ('owner'/'gather'/'adamw');
        elementwise variants force 'adamw' whatever ``config.mode`` says."""
        from repro.core.muon import _resolve
        return _resolve(self.config)[1]

    def replace(self, **overrides) -> "Muon":
        """A new Muon sharing this plan/mesh with config fields overridden
        (e.g. ``opt.replace(pipeline='bucketed')``)."""
        return Muon(self.plan, self.mesh,
                    config=replace(self.config, **overrides))

    def init(self, params) -> MuonState:
        return muon_init(self.plan, params, self.config, self.mesh)

    def update(self, grads, state: MuonState, params):
        return muon_update(self.plan, grads, state, params, self.config,
                           self.mesh)

    def update_staged(self, staged, rest_grads, state: MuonState, params):
        """Optimizer step from pre-staged owner-layout matrix gradients (the
        accumulation-overlapped bucketed pipeline; see core/pipeline.py)."""
        from repro.core.muon import muon_update_staged
        return muon_update_staged(self.plan, staged, rest_grads, state,
                                  params, self.config, self.mesh)

    # state-dict accessors (paper §4: "the state-dict accessors")
    def state_dict(self, state: MuonState) -> dict:
        return {"step": state.step, "momentum": state.momentum,
                "adamw_mu": state.adamw.mu, "adamw_nu": state.adamw.nu,
                "error_feedback": state.error_feedback,
                "variant_state": state.variant_state}

    def load_state_dict(self, d: dict) -> MuonState:
        from repro.core.muon import AdamWState
        return MuonState(step=d["step"], momentum=d["momentum"],
                         adamw=AdamWState(d["adamw_mu"], d["adamw_nu"]),
                         error_feedback=d.get("error_feedback"),
                         variant_state=d.get("variant_state"))


def reshard_owner_state(state, old_plan: DedicationPlan,
                        new_plan: DedicationPlan, new_mesh=None):
    """Elastic restart across owner counts (fault-tolerance substrate).

    Owner-layout buffers are padded to ``D·cap`` rows, so a checkpoint taken
    at D owners cannot be loaded verbatim onto D′ owners after a node
    failure.  This unpacks each group's owner-major buffers to their logical
    (count, ...) rows under the OLD plan and repacks/pads them under the NEW
    plan — semantics are exactly preserved (the pad rows are zeros and never
    consumed).  Covers the momentum stacks AND any per-variant state buffers
    (NorMuon neuron moments, MuonBP polar caches), all of which share the
    owner-major row layout.  AdamW moments and error feedback are
    training-layout pytrees and reshard by placement alone.
    """
    import jax

    from repro.core.muon import MuonState, group_key_str
    from repro.core.owner_comms import owner_sharding, repack_rows

    def repack_buffer(skey_to_key, skey, buf):
        old_g = old_plan.groups[skey_to_key[skey]]
        new_g = new_plan.groups[skey_to_key[skey]]
        if old_g.count != new_g.count:
            # A bare assert would vanish under `python -O` and let a
            # mismatched repack silently scramble logical rows.
            raise ValueError(
                f"reshard_owner_state: group {skey!r} has {old_g.count} "
                f"logical rows under the old plan but {new_g.count} under "
                f"the new plan — the plans describe different parameter "
                f"sets, not an owner-count change")
        packed = repack_rows(old_g, new_g, buf)
        shard = owner_sharding(new_plan, new_mesh, ndim=packed.ndim)
        if shard is not None:
            packed = jax.device_put(packed, shard)
        return packed

    skey_to_key = {group_key_str(k): k for k in old_plan.groups}
    new_momentum = {skey: repack_buffer(skey_to_key, skey, buf)
                    for skey, buf in state.momentum.items()}
    new_vstate = state.variant_state
    if new_vstate is not None:
        # variant state is {field: {group_key_str: owner buffer} | None};
        # None fields (e.g. NorMuon's stateless 'inner') must stay None so
        # the resharded tree structure matches a fresh muon_init's
        new_vstate = {
            field: None if bufs is None else
            {skey: repack_buffer(skey_to_key, skey, buf)
             for skey, buf in bufs.items()}
            for field, bufs in new_vstate.items()}
    return MuonState(step=state.step, momentum=new_momentum,
                     adamw=state.adamw,
                     error_feedback=state.error_feedback,
                     variant_state=new_vstate)
