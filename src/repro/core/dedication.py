"""Parameter dedication: classify, group, bucket and assign owners (§3.1/3.4/§4).

``dedicate_params`` is the planning half of the paper's three-line API.  It
walks the parameter pytree once at init and produces a ``DedicationPlan``:

* **classification** — 2-D hidden weight matrices (including scan-stacked
  ``(L, m, n)`` and MoE ``(L, E, m, n)`` leaves, which carry one matrix per
  leading index) take the Muon path; embeddings, heads, norms, biases,
  routers, convs and other <2-D leaves take AdamW through the host stack
  (paper line 16 of Alg. 1).
* **shape groups** — matrices grouped by post-transpose ``(m, n)`` (m ≤ n),
  the granularity at which costs are measured and batched kernels launch.
* **owner assignment** — the measured-cost MILP / greedy / ablation
  strategies of core/load_balance.py, one owner slot per matrix.
* **owner-major packed layout** — per group, an index permutation realizing
  the assignment as a capacity-padded stacked array ``(D·cap, m, n)`` whose
  leading axis is sharded over the owner mesh axes.  This is the SPMD
  realization of per-rank ownership (docs/DESIGN.md §2/§5): device r holds and
  updates exactly the matrices assigned to owner slot r.
* **Gram buckets** — groups with equal Gram dimension m are fused for the
  m×m iteration phase (the paper's shape-batched NS execution), maximizing
  the batch the symmetric kernels see.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import load_balance
from repro.core.load_balance import Assignment, CostModel, ShapeKey

# Name fragments excluded from the Muon path by default (AdamW instead).
DEFAULT_EXCLUDE = ("embed", "unembed", "head", "norm", "bias", "router",
                   "gate_w", "conv", "a_log", "dt_bias", "skip", "pos_enc",
                   "patch", "frame")
MIN_MATRIX_DIM = 8


def default_muon_predicate(path: str, shape: Tuple[int, ...],
                           exclude: Sequence[str] = DEFAULT_EXCLUDE) -> bool:
    """True if the leaf at ``path`` should be optimized by Muon."""
    if len(shape) < 2:
        return False
    if min(shape[-2:]) < MIN_MATRIX_DIM:
        return False
    low = path.lower()
    return not any(pat in low for pat in exclude)


@dataclass
class LeafInfo:
    path: str
    shape: Tuple[int, ...]          # full leaf shape
    count: int                      # matrices in the leaf (prod of lead dims)
    transpose: bool                 # True if matrices were transposed to m<=n
    group: ShapeKey                 # post-transpose (m, n)
    offset: int                     # start position in the group's flat order


@dataclass
class GroupPlan:
    key: ShapeKey                   # (m, n), m <= n
    leaf_paths: List[str]           # deterministic member order (schedule order)
    count: int
    owner_of: np.ndarray            # (count,)
    capacity: int                   # max matrices per owner (padding target)
    pack_index: np.ndarray          # (D*cap,) flat member index or -1 = pad
    unpack_index: np.ndarray        # (count,) position of member in packed stack

    @property
    def packed_size(self) -> int:
        return len(self.pack_index)


# NOTE on group granularity: execution groups are PER LEAF (one stacked
# (L[,E],m,n) parameter each).  Merging same-shape leaves into one packed
# stack looks tempting (bigger NS batches) but the per-leaf sections of the
# merged stack are not shard-aligned, so the unpack slices force XLA SPMD
# into whole-tensor rematerialization at 100B+ scale.  The *census* handed to
# the load balancer still aggregates by (m, n) across leaves — costs are
# shape-keyed (§3.4) — and leaves of equal Gram dim remain fusable in the
# iteration phase (bucket metadata).


@dataclass
class DedicationPlan:
    num_owners: int
    mesh_rows: int                  # slower owner axis extent (node analogue)
    mesh_cols: int                  # faster owner axis extent (column analogue)
    leaves: Dict[str, LeafInfo]
    adamw_paths: List[str]
    groups: Dict[str, GroupPlan]            # keyed by leaf path
    buckets: Dict[int, List[str]]           # gram-dim m -> group keys
    assignment: Assignment
    strategy: str
    cost_model: Optional[CostModel] = None
    owner_axes: Tuple[str, ...] = ()        # mesh axes the stack axis shards over
    stats: dict = field(default_factory=dict)
    # optional: per-leaf-path training PartitionSpecs; when set, pack/unpack
    # stage the owner reshard at identical stacked shapes (muon.py)
    train_specs: Optional[dict] = None

    def group_of(self, path: str) -> GroupPlan:
        return self.groups[path]


def _flatten_paths(params) -> List[Tuple[str, Tuple[int, ...]]]:
    import jax
    flat = jax.tree_util.tree_flatten_with_path(params)[0]
    out = []
    for kp, leaf in flat:
        path = "/".join(_key_str(k) for k in kp)
        out.append((path, tuple(leaf.shape)))
    return out


def _key_str(k) -> str:
    # DictKey('x') -> x, SequenceKey(3) -> 3, attr keys -> name
    for attr in ("key", "idx", "name"):
        if hasattr(k, attr):
            return str(getattr(k, attr))
    return str(k)


def dedicate_params(
    params,
    *,
    num_owners: int,
    mesh_rows: Optional[int] = None,
    mesh_cols: Optional[int] = None,
    strategy: str = "load_balance",
    predicate: Callable[[str, Tuple[int, ...]], bool] = default_muon_predicate,
    cost_model: Optional[CostModel] = None,
    cost_backend: str = "analytic",     # 'analytic' | 'measured'
    speed: Optional[np.ndarray] = None,
    owner_axes: Tuple[str, ...] = (),
    s_thr: int = load_balance.DEFAULT_S_THR,
    xor_order: bool = True,
    physical_layout: str = "contiguous",   # 'contiguous' | 'assignment'
) -> DedicationPlan:
    """Build the dedication plan (paper: ``dmuon.dedicate_params(model, mesh)``).

    ``params`` may be a pytree of arrays or of ShapeDtypeStructs (the dry-run
    path plans without allocating).  ``num_owners`` is the flattened owner
    mesh size; ``mesh_rows × mesh_cols`` factorize it for the XOR layout
    (defaults: rows = num_owners // cols heuristic).
    """
    if mesh_cols is None:
        mesh_cols = min(num_owners, 8 if num_owners % 8 == 0 else num_owners)
    if mesh_rows is None:
        mesh_rows = num_owners // mesh_cols
    assert mesh_rows * mesh_cols == num_owners, (mesh_rows, mesh_cols, num_owners)

    leaves: Dict[str, LeafInfo] = {}
    adamw_paths: List[str] = []
    group_members: Dict[ShapeKey, List[str]] = {}
    group_offsets: Dict[ShapeKey, int] = {}

    for path, shape in _flatten_paths(params):
        if not predicate(path, shape):
            adamw_paths.append(path)
            continue
        m0, n0 = shape[-2:]
        transpose = m0 > n0
        key: ShapeKey = (min(m0, n0), max(m0, n0))
        count = int(np.prod(shape[:-2])) if len(shape) > 2 else 1
        off = group_offsets.get(key, 0)
        leaves[path] = LeafInfo(path, shape, count, transpose, key, off)
        group_offsets[key] = off + count
        group_members.setdefault(key, []).append(path)

    shape_counts = {k: group_offsets[k] for k in group_members}

    if cost_model is None and strategy in ("load_balance", "greedy", "lpt"):
        if cost_backend == "measured":
            cost_model = load_balance.measured_cost_model(shape_counts)
        else:
            cost_model = load_balance.analytic_cost_model(shape_counts)

    assignment = load_balance.assign(
        shape_counts, num_owners, strategy=strategy, cost_model=cost_model,
        speed=speed, rows=mesh_rows, cols=mesh_cols, s_thr=s_thr)

    if xor_order and strategy not in ("xor", "rank0"):
        # Relabel owner ids through the XOR slot map (Eq. 3): the balancing
        # strategies fill owners in index order, so consecutive matrices tend
        # to land on consecutively-numbered owners; the relabeling spreads
        # those over distinct mesh columns / rotated rows, which is exactly
        # the contention-avoidance of the paper's fine-grained layout.
        # Makespan is invariant under owner relabeling.
        from repro.core.layout import owner_slot
        perm = np.asarray([owner_slot(r, mesh_rows, mesh_cols)
                           for r in range(num_owners)])
        if len(set(perm.tolist())) == num_owners:   # bijective only if R | C
            assignment = Assignment(
                num_owners,
                {k: perm[v] for k, v in assignment.owner_of.items()},
                {k: [(b, int(perm[r])) for b, r in v]
                 for k, v in assignment.chunks.items()},
                strategy=assignment.strategy + "+xor")

    groups: Dict[str, GroupPlan] = {}
    for path, info in leaves.items():
        key = info.group
        count = info.count
        if physical_layout == "contiguous":
            # SPMD realization: within a shape group every matrix has the
            # same cost, so balanced *contiguous* blocks are exactly as
            # optimal as any permuted assignment — and the pack becomes a
            # pad/reshape the partitioner shards cleanly.  An arbitrary
            # permutation gather forces XLA's "involuntary full
            # rematerialization" (whole-tensor replication) at 100B+ scale.
            # The strategy's assignment is kept as *logical* metadata (it is
            # what an MPMD runtime / the rank simulation benchmarks execute).
            capacity = max(1, -(-count // num_owners))
            pack_index = np.full(num_owners * capacity, -1, dtype=np.int64)
            pack_index[:count] = np.arange(count)
            unpack_index = np.arange(count, dtype=np.int64)
            owner_of = np.arange(count) // capacity
        else:
            owner_of = assignment.owner_of[key][info.offset:
                                                info.offset + count]
            counts_per_owner = np.bincount(owner_of, minlength=num_owners)
            capacity = max(1, int(counts_per_owner.max()))
            pack_index = np.full(num_owners * capacity, -1, dtype=np.int64)
            unpack_index = np.zeros(count, dtype=np.int64)
            cursor = np.zeros(num_owners, dtype=np.int64)
            for w in range(count):  # schedule order within owner segments
                r = owner_of[w]
                pos = r * capacity + cursor[r]
                cursor[r] += 1
                pack_index[pos] = w
                unpack_index[w] = pos
        groups[path] = GroupPlan(key, [path], count, owner_of, capacity,
                                 pack_index, unpack_index)

    buckets: Dict[int, List[str]] = {}
    for path in sorted(groups):
        buckets.setdefault(groups[path].key[0], []).append(path)

    total = sum(shape_counts.values())
    padded = sum(g.packed_size for g in groups.values())
    plan = DedicationPlan(
        num_owners=num_owners, mesh_rows=mesh_rows, mesh_cols=mesh_cols,
        leaves=leaves, adamw_paths=adamw_paths, groups=groups,
        buckets=buckets, assignment=assignment, strategy=assignment.strategy,
        cost_model=cost_model, owner_axes=tuple(owner_axes),
        stats={
            "num_matrices": total,
            "num_groups": len(groups),
            "num_buckets": len(buckets),
            "padded_matrices": padded,
            "padding_waste": (padded - total) / max(total, 1),
            "num_adamw_leaves": len(adamw_paths),
        })
    return plan
