"""Newton-Schulz iteration coefficient sets.

Two families, selectable per config (paper §4 "Configurations"):

* ``polar_express`` — the per-iteration optimal quintic coefficients of
  Amsel et al., "The Polar Express" (arXiv:2505.16932).  DMuon adopts these
  as the default for k = 5 NS steps.
* ``quintic`` — the standard fixed (a, b, c) quintic of the original Muon
  implementation (Jordan et al., 2024), identical at every iteration.

Each entry is an ``(a, b, c)`` triple applied as ``p(X) = aX + bX³ + cX⁵``
in the matrix sense, equivalently ``X' = (aI + bG + cG²) X`` with the Gram
matrix ``G = XXᵀ``.
"""

from __future__ import annotations

from typing import Sequence, Tuple

Coeffs = Tuple[float, float, float]

# Per-iteration Polar Express schedule (safety-factored), from the reference
# implementation accompanying arXiv:2505.16932.  Longer runs repeat the final
# (converged) triple, which is the fixed point of the optimal schedule.
POLAR_EXPRESS: Tuple[Coeffs, ...] = (
    (8.28721201814563, -23.595886519098837, 17.300387312530933),
    (4.107059111542203, -2.9478499167379106, 0.5448431082926601),
    (3.9486908534822946, -2.908902115962949, 0.5518191394370137),
    (3.3184196573706015, -2.488488024314874, 0.51004894012372),
    (2.300652019954817, -1.6689039845747493, 0.4188073119525673),
    (1.891301407787398, -1.2679958271945868, 0.37680408948524835),
    (1.8750014808534479, -1.2500016453999487, 0.3750001645474248),
    (1.875, -1.25, 0.375),
)

# Original Muon quintic, used for every iteration.
QUINTIC: Coeffs = (3.4445, -4.7750, 2.0315)


def get_coefficients(name: str, num_steps: int) -> Tuple[Coeffs, ...]:
    """Return the per-iteration ``(a, b, c)`` schedule for ``num_steps`` steps."""
    if num_steps < 1:
        raise ValueError(f"num_steps must be >= 1, got {num_steps}")
    if name == "polar_express":
        sched = list(POLAR_EXPRESS[:num_steps])
        while len(sched) < num_steps:  # repeat the converged triple
            sched.append(POLAR_EXPRESS[-1])
        return tuple(sched)
    if name == "quintic":
        return tuple(QUINTIC for _ in range(num_steps))
    raise ValueError(f"unknown coefficient schedule {name!r} "
                     "(expected 'polar_express' or 'quintic')")


def validate_schedule(schedule: Sequence[Coeffs]) -> None:
    """Sanity-check a user-provided schedule."""
    for i, abc in enumerate(schedule):
        if len(abc) != 3:
            raise ValueError(f"schedule[{i}] must be an (a, b, c) triple, got {abc!r}")
