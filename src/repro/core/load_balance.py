"""Computation-aware load balancing (paper §3.4).

Owner assignment is driven by a *measured* execution-cost model: parameters
are grouped by shape, each shape s has a candidate set of batch sizes B_s, and
``c_{s,b}`` is the measured (or, on non-TPU hosts, analytically modelled) time
of one owner-local batched Muon update.  Assignment is the MILP of Eq. 5:

    min  max_r Σ_{s,b} c_{s,b} · x_{s,b,r}
    s.t. Σ_{r,b} b · x_{s,b,r} = n_s            ∀s
         x_{s,b,r} ∈ Z≥0

solved once at init with SciPy's MILP; above a search-space threshold
``s_thr`` we fall back to a greedy assignment (paper: "bounded, predictable
initialization cost at large scale").  ``round_robin`` / ``rank0`` / ``lpt``
are kept as ablation handles (paper §4 "Ownership strategy plug-in").

Heterogeneity: every solver accepts per-owner ``speed`` factors (measured
step-time drift), which is how the straggler-mitigation hook re-balances a
degraded rank (runtime/elastic.py) — effective cost on owner r is c/speed_r.
"""

from __future__ import annotations

import heapq
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

ShapeKey = Tuple[int, int]          # (m, n) with m <= n (post-transpose)
DEFAULT_BATCH_SIZES = (1, 2, 4, 8, 16)
DEFAULT_S_THR = 4096                # max MILP decision variables (paper S_thr)


# --------------------------------------------------------------------------
# Cost models
# --------------------------------------------------------------------------

@dataclass
class CostModel:
    """c_{s,b}: cost (seconds) of one batch of shape s at batch size b."""
    costs: Dict[ShapeKey, Dict[int, float]] = field(default_factory=dict)

    def cost(self, shape: ShapeKey, batch: int) -> float:
        by_b = self.costs[shape]
        if batch in by_b:
            return by_b[batch]
        # interpolate: per-matrix cost of the nearest measured batch size
        bs = min(by_b, key=lambda b: abs(b - batch))
        return by_b[bs] / bs * batch

    def batch_sizes(self, shape: ShapeKey) -> List[int]:
        return sorted(self.costs[shape])

    def per_matrix(self, shape: ShapeKey) -> float:
        """Best achievable per-matrix cost over batch sizes."""
        by_b = self.costs[shape]
        return min(c / b for b, c in by_b.items())


# TPU v5e hardware constants (shared with launch/roofline.py)
PEAK_FLOPS = 197e12        # bf16 FLOP/s per chip
HBM_BW = 819e9             # bytes/s per chip
DISPATCH_OVERHEAD = 2e-6   # per kernel launch, amortized by batching


def analytic_cost_model(
    shapes: Dict[ShapeKey, int],
    batch_sizes: Sequence[int] = DEFAULT_BATCH_SIZES,
    *,
    ns_steps: int = 5,
    dtype_bytes: int = 4,
    symmetric_kernels: bool = True,
) -> CostModel:
    """Roofline cost of one batched Gram-NS update per (shape, batch).

    Mirrors the paper's observation that runtime depends on shape, batch size
    and kernel selection: small matrices are dispatch/memory bound and batch
    well (Fig. 7); large ones are compute bound and gain little.
    """
    from repro.core.gram_ns import gram_ns_flops
    cm = CostModel()
    for (m, n), _count in shapes.items():
        by_b: Dict[int, float] = {}
        for b in batch_sizes:
            fl = gram_ns_flops(m, n, ns_steps, batch=b,
                               symmetric_kernels=symmetric_kernels)
            flops = fl["gram_symmetric_kernel" if symmetric_kernels
                       else "gram_full_gemm"]
            # bytes: X in/out + Gram-space working set per step
            bytes_moved = b * dtype_bytes * (
                2 * m * n + (4 * ns_steps - 3) * 3 * m * m)
            t = max(flops / PEAK_FLOPS, bytes_moved / HBM_BW)
            # dispatch overhead: one launch per NS product for the whole batch
            t += DISPATCH_OVERHEAD * (4 * ns_steps - 1)
            by_b[b] = t
        cm.costs[(m, n)] = by_b
    return cm


def measured_cost_model(
    shapes: Dict[ShapeKey, int],
    batch_sizes: Sequence[int] = DEFAULT_BATCH_SIZES,
    *,
    ns_cfg=None,
    repeats: int = 2,
) -> CostModel:
    """Benchmark the complete owner-local execution path per (shape, batch).

    Includes batching behaviour, kernel implementation and autotuned schedule
    exactly as the runtime will execute them (paper: "directly reflects the
    actual execution characteristics of the target hardware").  On this
    container the target is XLA:CPU; on TPU the same code path times the
    compiled kernels.
    """
    import time

    import jax
    import jax.numpy as jnp

    from repro.core.gram_ns import GramNSConfig, gram_newton_schulz
    ns_cfg = ns_cfg or GramNSConfig()
    cm = CostModel()
    for (m, n), _count in shapes.items():
        by_b: Dict[int, float] = {}
        for b in batch_sizes:
            x = jax.random.normal(jax.random.PRNGKey(0), (b, m, n),
                                  dtype=jnp.float32)
            fn = jax.jit(lambda v: gram_newton_schulz(
                v, ns_cfg, assume_short_fat=True))
            fn(x).block_until_ready()          # compile
            best = float("inf")
            for _ in range(repeats):
                t0 = time.perf_counter()
                fn(x).block_until_ready()
                best = min(best, time.perf_counter() - t0)
            by_b[b] = best
        cm.costs[(m, n)] = by_b
    return cm


# --------------------------------------------------------------------------
# Assignment result
# --------------------------------------------------------------------------

@dataclass
class Assignment:
    """Owner of every matrix of every shape group, plus the chunking used."""
    num_owners: int
    owner_of: Dict[ShapeKey, np.ndarray]               # (n_s,) int owner ids
    chunks: Dict[ShapeKey, List[Tuple[int, int]]]      # (batch_size, owner)
    strategy: str = ""

    def loads(self, cm: CostModel,
              speed: Optional[np.ndarray] = None) -> np.ndarray:
        """Per-owner predicted time under cost model ``cm``."""
        loads = np.zeros(self.num_owners)
        for shape, chunk_list in self.chunks.items():
            for b, r in chunk_list:
                loads[r] += cm.cost(shape, b)
        if speed is not None:
            loads = loads / np.asarray(speed)
        return loads

    def makespan(self, cm: CostModel,
                 speed: Optional[np.ndarray] = None) -> float:
        return float(self.loads(cm, speed).max())

    def counts(self) -> Dict[ShapeKey, np.ndarray]:
        """Matrices per owner per shape (drives SPMD capacity padding)."""
        out = {}
        for shape, owners in self.owner_of.items():
            out[shape] = np.bincount(owners, minlength=self.num_owners)
        return out


def _expand_owner_of(shape_counts, chunks) -> Dict[ShapeKey, np.ndarray]:
    owner_of = {}
    for shape, n in shape_counts.items():
        ids = []
        for b, r in chunks[shape]:
            ids.extend([r] * b)
        assert len(ids) == n, (shape, len(ids), n)
        owner_of[shape] = np.asarray(ids, dtype=np.int64)
    return owner_of


# --------------------------------------------------------------------------
# Strategies
# --------------------------------------------------------------------------

def solve_milp(
    shape_counts: Dict[ShapeKey, int],
    cost_model: CostModel,
    num_owners: int,
    *,
    speed: Optional[np.ndarray] = None,
    s_thr: int = DEFAULT_S_THR,
    time_limit: float = 10.0,
) -> Assignment:
    """Exact Eq. 5 via SciPy MILP; greedy fallback above ``s_thr`` variables."""
    from scipy import optimize, sparse

    shapes = list(shape_counts)
    var_index: List[Tuple[ShapeKey, int, int]] = []   # (shape, b, r)
    for s in shapes:
        for b in cost_model.batch_sizes(s):
            for r in range(num_owners):
                var_index.append((s, b, r))
    nvar = len(var_index)
    if nvar > s_thr:
        return solve_greedy(shape_counts, cost_model, num_owners, speed=speed)

    spd = np.ones(num_owners) if speed is None else np.asarray(speed, float)
    # variables: x (nvar) + t (1); objective: minimize t
    c_obj = np.zeros(nvar + 1)
    c_obj[-1] = 1.0

    rows, cols, vals = [], [], []
    b_ub = []
    # load constraints: Σ c_{s,b}/spd_r · x_{s,b,r} − t ≤ 0   ∀r
    for r in range(num_owners):
        for vi, (s, b, rr) in enumerate(var_index):
            if rr == r:
                rows.append(r)
                cols.append(vi)
                vals.append(cost_model.cost(s, b) / spd[r])
        rows.append(r)
        cols.append(nvar)
        vals.append(-1.0)
        b_ub.append(0.0)
    a_ub = sparse.csr_matrix((vals, (rows, cols)),
                             shape=(num_owners, nvar + 1))

    rows, cols, vals = [], [], []
    b_eq = []
    # coverage: Σ_{r,b} b · x_{s,b,r} = n_s   ∀s
    for si, s in enumerate(shapes):
        for vi, (ss, b, r) in enumerate(var_index):
            if ss == s:
                rows.append(si)
                cols.append(vi)
                vals.append(float(b))
        b_eq.append(float(shape_counts[s]))
    a_eq = sparse.csr_matrix((vals, (rows, cols)),
                             shape=(len(shapes), nvar + 1))

    constraints = [
        optimize.LinearConstraint(a_ub, -np.inf, np.asarray(b_ub)),
        optimize.LinearConstraint(a_eq, np.asarray(b_eq), np.asarray(b_eq)),
    ]
    integrality = np.concatenate([np.ones(nvar), [0.0]])
    bounds = optimize.Bounds(np.zeros(nvar + 1), np.full(nvar + 1, np.inf))
    # A 2% MIP gap + time limit keeps the one-time solve bounded (paper:
    # "bounded, predictable initialization cost"); accept the incumbent even
    # when optimality was not proven within the limit.
    res = optimize.milp(c_obj, constraints=constraints,
                        integrality=integrality, bounds=bounds,
                        options={"time_limit": time_limit,
                                 "mip_rel_gap": 0.02})
    if res.x is None:
        return solve_greedy(shape_counts, cost_model, num_owners, speed=speed)

    x = np.round(res.x[:nvar]).astype(int)
    chunks: Dict[ShapeKey, List[Tuple[int, int]]] = {s: [] for s in shapes}
    remaining = dict(shape_counts)
    loads = np.zeros(num_owners)
    for vi, (s, b, r) in enumerate(var_index):
        for _ in range(x[vi]):
            take = min(b, remaining[s])
            if take > 0:
                chunks[s].append((take, r))
                remaining[s] -= take
                loads[r] += cost_model.cost(s, take) / spd[r]
    # numerical slack from rounding: top up any remainder onto the least
    # loaded owner
    for s in shapes:
        while remaining[s] > 0:
            r = int(np.argmin(loads))
            chunks[s].append((1, r))
            remaining[s] -= 1
            loads[r] += cost_model.cost(s, 1) / spd[r]

    asn = Assignment(num_owners, _expand_owner_of(shape_counts, chunks),
                     chunks, strategy="milp")
    return asn


def solve_greedy(
    shape_counts: Dict[ShapeKey, int],
    cost_model: CostModel,
    num_owners: int,
    *,
    speed: Optional[np.ndarray] = None,
) -> Assignment:
    """Greedy fallback (paper: used when MILP search space exceeds S_thr).

    For each shape pick the most batch-efficient chunk size, then assign
    chunks to the least-loaded owner, largest-cost shapes first (LPT over
    measured chunk costs).
    """
    spd = np.ones(num_owners) if speed is None else np.asarray(speed, float)
    # order shapes by total best-case work, largest first
    order = sorted(shape_counts,
                   key=lambda s: -cost_model.per_matrix(s) * shape_counts[s])
    heap = [(0.0, r) for r in range(num_owners)]
    heapq.heapify(heap)
    chunks: Dict[ShapeKey, List[Tuple[int, int]]] = {s: [] for s in shape_counts}
    for s in order:
        by_b = {b: cost_model.cost(s, b) for b in cost_model.batch_sizes(s)}
        b_star = min(by_b, key=lambda b: by_b[b] / b)   # best per-matrix cost
        n = shape_counts[s]
        # Batching efficiency vs balance granularity: cap the chunk size so
        # every owner can participate in this shape's work (the measured-cost
        # analogue of even spreading), but never below 1.  Under heterogeneous
        # owner speeds (straggler rebalancing) halve the granularity again so
        # a slow owner can actually shed load.
        denom = num_owners if (speed is None or np.ptp(spd) == 0) \
            else 2 * num_owners
        b_eff = max(1, min(b_star, -(-n // denom)))
        while n > 0:
            take = min(b_eff, n)
            load, r = heapq.heappop(heap)
            chunks[s].append((take, r))
            heapq.heappush(heap, (load + cost_model.cost(s, take) / spd[r], r))
            n -= take
    return Assignment(num_owners, _expand_owner_of(shape_counts, chunks),
                      chunks, strategy="greedy")


def solve_lpt(
    shape_counts: Dict[ShapeKey, int],
    cost_model: CostModel,
    num_owners: int,
    *,
    speed: Optional[np.ndarray] = None,
) -> Assignment:
    """Classic Longest-Processing-Time at single-matrix granularity —
    the analytical baseline the paper contrasts with (no batching effects)."""
    spd = np.ones(num_owners) if speed is None else np.asarray(speed, float)
    items = []
    for s, n in shape_counts.items():
        c = cost_model.cost(s, 1)
        items.extend([(c, s)] * n)
    items.sort(key=lambda t: -t[0])
    heap = [(0.0, r) for r in range(num_owners)]
    heapq.heapify(heap)
    chunks: Dict[ShapeKey, List[Tuple[int, int]]] = {s: [] for s in shape_counts}
    for c, s in items:
        load, r = heapq.heappop(heap)
        chunks[s].append((1, r))
        heapq.heappush(heap, (load + c / spd[r], r))
    return Assignment(num_owners, _expand_owner_of(shape_counts, chunks),
                      chunks, strategy="lpt")


def round_robin(shape_counts: Dict[ShapeKey, int],
                num_owners: int) -> Assignment:
    """Naive round-robin (ablation handle)."""
    chunks: Dict[ShapeKey, List[Tuple[int, int]]] = {}
    w = 0
    for s, n in shape_counts.items():
        chunks[s] = [(1, (w + i) % num_owners) for i in range(n)]
        w += n
    return Assignment(num_owners, _expand_owner_of(shape_counts, chunks),
                      chunks, strategy="round_robin")


def rank0(shape_counts: Dict[ShapeKey, int], num_owners: int) -> Assignment:
    """All matrices on owner 0 (ablation: load balancing removed entirely)."""
    chunks = {s: [(n, 0)] if n else [] for s, n in shape_counts.items()}
    owner_of = {s: np.zeros(n, dtype=np.int64) for s, n in shape_counts.items()}
    return Assignment(num_owners, owner_of, chunks, strategy="rank0")


def xor_layout(shape_counts: Dict[ShapeKey, int], num_owners: int, *,
               rows: int, cols: int) -> Assignment:
    """Owner = XOR fine-grained slot of the matrix's schedule index (Eq. 3)."""
    from repro.core.layout import owner_slot
    assert rows * cols == num_owners
    chunks: Dict[ShapeKey, List[Tuple[int, int]]] = {}
    w = 0
    for s, n in shape_counts.items():
        chunks[s] = [(1, owner_slot(w + i, rows, cols)) for i in range(n)]
        w += n
    return Assignment(num_owners, _expand_owner_of(shape_counts, chunks),
                      chunks, strategy="xor")


STRATEGIES = {
    "load_balance": solve_milp,
    "greedy": solve_greedy,
    "lpt": solve_lpt,
}


def assign(
    shape_counts: Dict[ShapeKey, int],
    num_owners: int,
    *,
    strategy: str = "load_balance",
    cost_model: Optional[CostModel] = None,
    speed: Optional[np.ndarray] = None,
    rows: Optional[int] = None,
    cols: Optional[int] = None,
    s_thr: int = DEFAULT_S_THR,
) -> Assignment:
    """Front door used by dedicate_params."""
    if strategy == "round_robin":
        return round_robin(shape_counts, num_owners)
    if strategy == "rank0":
        return rank0(shape_counts, num_owners)
    if strategy == "xor":
        return xor_layout(shape_counts, num_owners,
                          rows=rows or 1, cols=cols or num_owners)
    cm = cost_model or analytic_cost_model(shape_counts)
    if strategy == "load_balance":
        return solve_milp(shape_counts, cm, num_owners, speed=speed,
                          s_thr=s_thr)
    if strategy in STRATEGIES:
        return STRATEGIES[strategy](shape_counts, cm, num_owners, speed=speed)
    raise ValueError(f"unknown ownership strategy {strategy!r}")
