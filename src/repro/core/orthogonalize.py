"""Pluggable orthogonalization backends (the NS layer of the optimizer).

The DMuon pipeline factors into layout → orthogonalize → update rule; this
module is the middle layer.  Every backend implements the same protocol:

    class Orthogonalizer:
        name: str
        def init_state(self, layout, cfg) -> dict | None: ...
        def __call__(self, stacks, *, step, state, layout, cfg)
            -> (ortho_stacks, new_state)

``stacks`` is a dict of owner-major (D·cap, m, n) buffers keyed by the
sanitized group key (``group_key_str``); ``layout`` is the bound
:class:`~repro.core.owner_comms.OwnerLayout`; ``cfg`` is the MuonConfig
(duck-typed — only ``ns`` and the variant knobs are read).  Stateless
backends return ``state`` unchanged (None).

Backends:

  gram           — batched Gram Newton-Schulz per shape group (the default
                   DMuon path, provably local under shard_map).
  gram_fused     — one batched m×m Gram recurrence per Gram bucket
                   (paper §3.3 shape-batched execution at its widest).
  full_ns        — full-matrix standard NS (the Muon-AG baseline compute).
  normuon        — NorMuon (arXiv:2510.05491): wraps a base backend and adds
                   neuron-wise second-moment normalization of the
                   orthogonalized update, rescaled to preserve each matrix's
                   update norm.  State: one (D·cap, m) fp32 moment per group.
  block_periodic — MuonBP (arXiv:2510.16981): full Gram NS only every
                   ``cfg.muonbp_period`` steps; in between, the cached polar
                   accumulator Q (a polynomial in the refresh-step Gram
                   matrix) is reapplied to the fresh normalized momentum —
                   one m×n GEMM instead of the whole iteration.  State: one
                   (D·cap, m, m) fp32 Q cache per group.  With period 1 every
                   step refreshes, which is bit-identical to ``gram``.

``make_orthogonalizer(cfg)`` resolves a MuonConfig to a composed backend via
the registry; the variant → backend mapping lives with the variant registry
in ``core/api.py``.
"""

from __future__ import annotations

import functools
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.core.gram_ns import (GramNSConfig, gram_finish, gram_iterate,
                                gram_newton_schulz, gram_prepare)
from repro.core.newton_schulz import newton_schulz
from repro.core.owner_comms import OwnerLayout, group_key_str

_EPS = 1e-7


class Orthogonalizer:
    """Protocol base: stateless identity-free orthogonalizer."""

    name = "base"

    def init_state(self, layout: OwnerLayout, cfg) -> Optional[dict]:
        return None

    def __call__(self, stacks: Dict[str, jax.Array], *, step, state,
                 layout: OwnerLayout, cfg):
        raise NotImplementedError


class GramNS(Orthogonalizer):
    """Batched Gram NS per shape group — the owner-local DMuon default."""

    name = "gram"

    def __call__(self, stacks, *, step, state, layout, cfg):
        ns = cfg.ns
        base = functools.partial(gram_newton_schulz, cfg=ns,
                                 assume_short_fat=True)

        def one(x):
            if ns.owner_chunk and x.shape[0] > ns.owner_chunk \
                    and x.shape[0] % ns.owner_chunk == 0:
                # bound the live Gram working set: sequential chunks of the
                # owner-local batch (memory policy for 1T-class censuses)
                xc = x.reshape((-1, ns.owner_chunk) + x.shape[1:])
                return jax.lax.map(base, xc).reshape(x.shape)
            return base(x)

        out = {k: layout.shard_local(one, v) for k, v in stacks.items()}
        return out, state


class BucketFusedGramNS(Orthogonalizer):
    """Bucket-fused owner NS: one batched m×m recurrence per Gram bucket.

    Phases (core/gram_ns.py): per-group prepare (normalize + SYRK, shapes
    differ in n), concat the Gram stacks of every group in the bucket,
    ONE batched iterate, split Q back, per-group finish (Q·X₀).  All inside
    a single shard_map so the whole optimizer phase is one local region."""

    name = "gram_fused"

    def __call__(self, stacks, *, step, state, layout, cfg):
        ns = cfg.ns
        buckets = layout.plan.buckets

        def run(sts: Dict[str, jax.Array]) -> Dict[str, jax.Array]:
            out: Dict[str, jax.Array] = {}
            for m_dim, keys in buckets.items():
                keys_here = [group_key_str(k) for k in keys
                             if group_key_str(k) in sts]
                if not keys_here:
                    continue
                x0s, gs, sizes = [], [], []
                for k in keys_here:
                    x0, g = gram_prepare(sts[k], ns)
                    x0s.append(x0)
                    gs.append(g)
                    sizes.append(g.shape[0])
                q_all = gram_iterate(jnp.concatenate(gs, axis=0), ns)
                off = 0
                for k, x0, sz in zip(keys_here, x0s, sizes):
                    out[k] = gram_finish(q_all[off:off + sz], x0,
                                         sts[k].dtype)
                    off += sz
            return out

        return layout.shard_local(run, stacks), state


class FullMatrixNS(Orthogonalizer):
    """Full-matrix standard NS — the replicated Muon-AG baseline compute.
    Accepts arbitrarily-shaped (..., r, c) leaves (training layout)."""

    name = "full_ns"

    def __call__(self, stacks, *, step, state, layout, cfg):
        out = {k: newton_schulz(v, num_steps=cfg.ns.num_steps,
                                schedule=cfg.ns.schedule)
               for k, v in stacks.items()}
        return out, state


class NeuronwiseNorm(Orthogonalizer):
    """NorMuon-style neuron-wise normalization on top of a base backend.

    After orthogonalization, each output row (neuron) is divided by the
    bias-corrected RMS of its own update history (second moment with decay
    ``cfg.normuon_beta2``), then the whole matrix is rescaled to its
    pre-normalization Frobenius norm — equalizing per-neuron effective rates
    without disturbing the update magnitude the scale rule expects.
    All ops are elementwise/rowwise on the stack, so they partition locally
    along the owner axis without an explicit shard_map.
    """

    name = "normuon"

    def __init__(self, inner: Orthogonalizer):
        self.inner = inner

    def init_state(self, layout, cfg):
        v = {group_key_str(k): layout.zeros(k, jnp.float32,
                                            trailing=(layout.plan.groups[k].key[0],))
             for k in layout.group_keys}
        return {"v": v, "inner": self.inner.init_state(layout, cfg)}

    def __call__(self, stacks, *, step, state, layout, cfg):
        ortho, inner_state = self.inner(stacks, step=step,
                                        state=state.get("inner"),
                                        layout=layout, cfg=cfg)
        b2 = cfg.normuon_beta2
        eps = cfg.normuon_eps
        bc = 1.0 - b2 ** (step.astype(jnp.float32) + 1.0)
        new_v: Dict[str, jax.Array] = {}
        out: Dict[str, jax.Array] = {}
        for k, o in ortho.items():
            o32 = o.astype(jnp.float32)
            row_ms = jnp.mean(jnp.square(o32), axis=-1)            # (B, m)
            v = b2 * state["v"][k] + (1.0 - b2) * row_ms
            new_v[k] = layout.constrain_buffer(v)
            o_n = o32 / (jnp.sqrt(v / bc) + eps)[..., None]
            norm = jnp.linalg.norm(o32, axis=(-2, -1), keepdims=True)
            norm_n = jnp.linalg.norm(o_n, axis=(-2, -1), keepdims=True)
            out[k] = (o_n * norm / (norm_n + _EPS)).astype(o.dtype)
        return out, {"v": new_v, "inner": inner_state}


class BlockPeriodicGramNS(Orthogonalizer):
    """MuonBP-style block-periodic orthogonalization.

    Refresh steps (``step % cfg.muonbp_period == 0``) run the full Gram NS
    and cache the polar accumulator Q_k; in-between steps reuse the cached
    Q on the freshly normalized momentum — amortizing the 4k−3 symmetric
    products of the iteration down to a single m×n product per step."""

    name = "block_periodic"

    def init_state(self, layout, cfg):
        q = {group_key_str(k): layout.zeros(
                k, jnp.float32,
                trailing=(layout.plan.groups[k].key[0],) * 2)
             for k in layout.group_keys}
        return {"q": q}

    def __call__(self, stacks, *, step, state, layout, cfg):
        ns = cfg.ns
        cdtype = jnp.dtype(ns.compute_dtype)

        def do_refresh(operands):
            sts, _ = operands

            def run(sts_in):
                out, newq = {}, {}
                for k, x in sts_in.items():
                    x0, g = gram_prepare(x, ns)
                    q = gram_iterate(g, ns)
                    out[k] = gram_finish(q, x0, x.dtype)
                    newq[k] = q.astype(jnp.float32)
                return out, newq

            return layout.shard_local(run, sts)

        def do_reuse(operands):
            sts, qs = operands

            def run(args):
                sts_in, qs_in = args["stacks"], args["q"]
                out = {}
                for k, x in sts_in.items():
                    norm = jnp.sqrt(jnp.sum(
                        jnp.square(x.astype(jnp.float32)),
                        axis=(-2, -1), keepdims=True))
                    x0 = x.astype(cdtype) / (norm + _EPS).astype(cdtype)
                    out[k] = gram_finish(qs_in[k].astype(cdtype), x0, x.dtype)
                return out, qs_in

            return layout.shard_local(run, {"stacks": sts, "q": qs})

        period = max(1, int(cfg.muonbp_period))
        if period == 1:
            out, new_q = do_refresh((stacks, state["q"]))
        else:
            out, new_q = jax.lax.cond(step % period == 0, do_refresh,
                                      do_reuse, (stacks, state["q"]))
        return out, {"q": new_q}


ORTHOGONALIZERS = {
    "gram": GramNS,
    "gram_fused": BucketFusedGramNS,
    "full_ns": FullMatrixNS,
    "block_periodic": BlockPeriodicGramNS,
}


def make_orthogonalizer(name: str, cfg) -> Orthogonalizer:
    """Build the backend for ``name``, honoring ``cfg.ns.bucket_fusion``.

    ``"normuon"`` composes the neuron-wise normalizer over the base Gram
    path; ``"auto"`` is the plain DMuon dispatch (fused when configured)."""
    base = BucketFusedGramNS() if cfg.ns.bucket_fusion else GramNS()
    if name in ("auto", "gram_auto"):
        return base
    if name == "normuon":
        return NeuronwiseNorm(base)
    try:
        return ORTHOGONALIZERS[name]()
    except KeyError:
        raise ValueError(
            f"unknown orthogonalizer {name!r}; "
            f"known: {sorted(ORTHOGONALIZERS) + ['auto', 'normuon']}")
