"""Pluggable orthogonalization backends (the NS layer of the optimizer).

The DMuon pipeline factors into layout → orthogonalize → update rule; this
module is the middle layer.  Every backend implements the same protocol:

    class Orthogonalizer:
        name: str
        def init_state(self, layout, cfg) -> dict | None: ...
        def __call__(self, stacks, *, step, state, layout, cfg)
            -> (ortho_stacks, new_state)

``stacks`` is a dict of owner-major (D·cap, m, n) buffers keyed by the
sanitized group key (``group_key_str``); ``layout`` is the bound
:class:`~repro.core.owner_comms.OwnerLayout`; ``cfg`` is the MuonConfig
(duck-typed — only ``ns`` and the variant knobs are read).  Stateless
backends return ``state`` unchanged (None).

Backends:

  gram           — batched Gram Newton-Schulz per shape group (the default
                   DMuon path, provably local under shard_map).
  gram_fused     — one batched m×m Gram recurrence per Gram bucket
                   (paper §3.3 shape-batched execution at its widest).
  full_ns        — full-matrix standard NS (the Muon-AG baseline compute).
  normuon        — NorMuon (arXiv:2510.05491): wraps a base backend and adds
                   neuron-wise second-moment normalization of the
                   orthogonalized update, rescaled to preserve each matrix's
                   update norm.  State: one (D·cap, m) fp32 moment per group.
  block_periodic — MuonBP (arXiv:2510.16981): full Gram NS only every
                   ``cfg.muonbp_period`` steps; in between, the cached polar
                   accumulator Q (a polynomial in the refresh-step Gram
                   matrix) is reapplied to the fresh normalized momentum —
                   one m×n GEMM instead of the whole iteration.  State: one
                   (D·cap, m, m) fp32 Q cache per group.  With period 1 every
                   step refreshes, which is bit-identical to ``gram``.
  dion2          — Dion2-style rank shrinking (arXiv:2512.16928): keep a
                   warm-started orthonormal rank-r basis Q per matrix, shrink
                   the momentum to the r×n factor QᵀM, run the batched Gram
                   NS on the factor only (Gram dimension r instead of m),
                   and reconstruct the full update as Q·NS(QᵀM).  State: one
                   (D·cap, m, r) fp32 factor basis per group.
  adamuon        — AdaMuon (arXiv:2507.11005): wraps a base backend and adds
                   elementwise second-moment adaptation of the orthogonalized
                   update (bias-corrected, then rescaled to preserve each
                   matrix's update norm — the magnitude the RMS-matching
                   scale rule expects).  State: one (D·cap, m, n) fp32
                   moment per group.

``make_orthogonalizer(name, cfg)`` resolves a backend name to a (possibly
composed) backend; ``known_orthogonalizers()`` is the single source of truth
for every name it accepts.  The variant → backend mapping lives with the
variant registry in ``core/api.py``.
"""

from __future__ import annotations

import functools
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.gram_ns import (GramNSConfig, gram_finish, gram_iterate,
                                gram_newton_schulz, gram_prepare)
from repro.core.newton_schulz import newton_schulz
from repro.core.owner_comms import OwnerLayout, group_key_str
from repro.core.update_rules import norm_preserving_rescale

_EPS = 1e-7


class Orthogonalizer:
    """Protocol base: stateless identity-free orthogonalizer."""

    name = "base"

    def init_state(self, layout: OwnerLayout, cfg) -> Optional[dict]:
        return None

    def __call__(self, stacks: Dict[str, jax.Array], *, step, state,
                 layout: OwnerLayout, cfg):
        raise NotImplementedError


class GramNS(Orthogonalizer):
    """Batched Gram NS per shape group — the owner-local DMuon default."""

    name = "gram"

    def __call__(self, stacks, *, step, state, layout, cfg):
        ns = cfg.ns
        base = functools.partial(gram_newton_schulz, cfg=ns,
                                 assume_short_fat=True)

        def one(x):
            if ns.owner_chunk and x.shape[0] > ns.owner_chunk \
                    and x.shape[0] % ns.owner_chunk == 0:
                # bound the live Gram working set: sequential chunks of the
                # owner-local batch (memory policy for 1T-class censuses)
                xc = x.reshape((-1, ns.owner_chunk) + x.shape[1:])
                return jax.lax.map(base, xc).reshape(x.shape)
            return base(x)

        out = {k: layout.shard_local(one, v) for k, v in stacks.items()}
        return out, state


class BucketFusedGramNS(Orthogonalizer):
    """Bucket-fused owner NS: one batched m×m recurrence per Gram bucket.

    Phases (core/gram_ns.py): per-group prepare (normalize + SYRK, shapes
    differ in n), concat the Gram stacks of every group in the bucket,
    ONE batched iterate, split Q back, per-group finish (Q·X₀).  All inside
    a single shard_map so the whole optimizer phase is one local region."""

    name = "gram_fused"

    def __call__(self, stacks, *, step, state, layout, cfg):
        ns = cfg.ns
        buckets = layout.plan.buckets

        def run(sts: Dict[str, jax.Array]) -> Dict[str, jax.Array]:
            out: Dict[str, jax.Array] = {}
            for m_dim, keys in buckets.items():
                keys_here = [group_key_str(k) for k in keys
                             if group_key_str(k) in sts]
                if not keys_here:
                    continue
                x0s, gs, sizes = [], [], []
                for k in keys_here:
                    x0, g = gram_prepare(sts[k], ns)
                    x0s.append(x0)
                    gs.append(g)
                    sizes.append(g.shape[0])
                q_all = gram_iterate(jnp.concatenate(gs, axis=0), ns)
                off = 0
                for k, x0, sz in zip(keys_here, x0s, sizes):
                    out[k] = gram_finish(q_all[off:off + sz], x0,
                                         sts[k].dtype)
                    off += sz
            return out

        return layout.shard_local(run, stacks), state


class FullMatrixNS(Orthogonalizer):
    """Full-matrix standard NS — the replicated Muon-AG baseline compute.
    Accepts arbitrarily-shaped (..., r, c) leaves (training layout)."""

    name = "full_ns"

    def __call__(self, stacks, *, step, state, layout, cfg):
        out = {k: newton_schulz(v, num_steps=cfg.ns.num_steps,
                                schedule=cfg.ns.schedule)
               for k, v in stacks.items()}
        return out, state


class NeuronwiseNorm(Orthogonalizer):
    """NorMuon-style neuron-wise normalization on top of a base backend.

    After orthogonalization, each output row (neuron) is divided by the
    bias-corrected RMS of its own update history (second moment with decay
    ``cfg.normuon_beta2``), then the whole matrix is rescaled to its
    pre-normalization Frobenius norm — equalizing per-neuron effective rates
    without disturbing the update magnitude the scale rule expects.
    All ops are elementwise/rowwise on the stack, so they partition locally
    along the owner axis without an explicit shard_map.
    """

    name = "normuon"

    def __init__(self, inner: Orthogonalizer):
        self.inner = inner

    def init_state(self, layout, cfg):
        v = {group_key_str(k): layout.zeros(k, jnp.float32,
                                            trailing=(layout.plan.groups[k].key[0],))
             for k in layout.group_keys}
        return {"v": v, "inner": self.inner.init_state(layout, cfg)}

    def __call__(self, stacks, *, step, state, layout, cfg):
        ortho, inner_state = self.inner(stacks, step=step,
                                        state=state.get("inner"),
                                        layout=layout, cfg=cfg)
        b2 = cfg.normuon_beta2
        eps = cfg.normuon_eps
        bc = 1.0 - b2 ** (step.astype(jnp.float32) + 1.0)
        new_v: Dict[str, jax.Array] = {}
        out: Dict[str, jax.Array] = {}
        for k, o in ortho.items():
            o32 = o.astype(jnp.float32)
            row_ms = jnp.mean(jnp.square(o32), axis=-1)            # (B, m)
            v = b2 * state["v"][k] + (1.0 - b2) * row_ms
            new_v[k] = layout.constrain_buffer(v)
            o_n = o32 / (jnp.sqrt(v / bc) + eps)[..., None]
            out[k] = norm_preserving_rescale(o_n, o32).astype(o.dtype)
        return out, {"v": new_v, "inner": inner_state}


class AdaptiveSecondMoment(Orthogonalizer):
    """AdaMuon-style elementwise second-moment adaptation over a base backend.

    After orthogonalization, every entry of the update is divided by the
    bias-corrected RMS of its own history (second moment with decay
    ``cfg.adamuon_beta2``), then the whole matrix is rescaled to its
    pre-adaptation Frobenius norm — per-coordinate adaptivity without
    disturbing the update magnitude the scale rule expects.  Structurally
    the elementwise sibling of :class:`NeuronwiseNorm` (whose ``v`` is
    per-row); all ops partition locally along the owner axis.
    """

    name = "adamuon"

    def __init__(self, inner: Orthogonalizer):
        self.inner = inner

    def init_state(self, layout, cfg):
        v = {group_key_str(k): layout.zeros(k, jnp.float32)
             for k in layout.group_keys}
        return {"v": v, "inner": self.inner.init_state(layout, cfg)}

    def __call__(self, stacks, *, step, state, layout, cfg):
        ortho, inner_state = self.inner(stacks, step=step,
                                        state=state.get("inner"),
                                        layout=layout, cfg=cfg)
        b2 = cfg.adamuon_beta2
        eps = cfg.adamuon_eps
        bc = 1.0 - b2 ** (step.astype(jnp.float32) + 1.0)
        new_v: Dict[str, jax.Array] = {}
        out: Dict[str, jax.Array] = {}
        for k, o in ortho.items():
            o32 = o.astype(jnp.float32)
            v = b2 * state["v"][k] + (1.0 - b2) * jnp.square(o32)
            new_v[k] = layout.constrain_buffer(v)
            o_n = o32 / (jnp.sqrt(v / bc) + eps)
            out[k] = norm_preserving_rescale(o_n, o32).astype(o.dtype)
        return out, {"v": new_v, "inner": inner_state}


def dion2_rank(m: int, cfg) -> int:
    """Factor rank r for a group with Gram dimension ``m`` under
    ``cfg.dion2_rank_frac`` (validated here, the single entry point)."""
    frac = float(cfg.dion2_rank_frac)
    if not 0.0 < frac <= 1.0:
        raise ValueError(
            f"dion2_rank_frac must be in (0, 1], got {frac}")
    return max(1, min(m, int(round(frac * m))))


class Dion2GramNS(Orthogonalizer):
    """Dion2-style shrunken-factor orthogonalization (arXiv:2512.16928).

    Instead of orthogonalizing the full m×n momentum, keep a persistent
    orthonormal rank-r basis Q per matrix and orthogonalize only the r×n
    factor:

        Z = M (Mᵀ Q_prev)          warm-started subspace iteration
        Q = qr(Z)                  re-orthonormalize the basis
        U = Q · NS(Qᵀ M) · √(m/r)  Gram NS on the factor, reconstruct

    The Gram recurrence runs at dimension r = ``dion2_rank``(m, cfg) instead
    of m, cutting the iteration cost from O(m²n + k·m³) to
    O(mnr + r²n + k·r³) — the algorithmic FLOP reduction that composes with
    the systems-level owner pipeline.  The √(m/r) rescale restores the
    Frobenius norm a fully orthogonalized update would have (‖NS(M)‖²_F = m,
    ‖Q·NS(QᵀM)‖²_F = r), so the RMS-matching scale rule sees the magnitude
    it expects.

    A cold basis (all-zero rows: fresh init, or pad rows reset by an elastic
    repack) falls back to the leading-r row selector — the literal "shrink to
    a submatrix" step — and warms onto the top singular subspace of the
    momentum from the next step on.  The update is invariant to the QR sign
    convention (NS is an odd function: Q·NS(QᵀM) = (QS)·NS((QS)ᵀM) for any
    diagonal sign matrix S), so determinism only requires a deterministic QR.

    State: one (D·cap, m, r) fp32 basis per group, owner-sharded and
    elastically resharded row-wise like every other owner buffer.
    """

    name = "dion2"

    def init_state(self, layout, cfg):
        q = {}
        for k in layout.group_keys:
            m = layout.plan.groups[k].key[0]
            q[group_key_str(k)] = layout.zeros(
                k, jnp.float32, trailing=(m, dion2_rank(m, cfg)))
        return {"q": q}

    def __call__(self, stacks, *, step, state, layout, cfg):
        ns = cfg.ns

        def run(args):
            sts, qs = args["stacks"], args["q"]
            out, new_q = {}, {}
            for k, x in sts.items():
                m = x.shape[-2]
                r = qs[k].shape[-1]
                x32 = x.astype(jnp.float32)
                q_prev = qs[k]
                # one warm-started subspace iteration toward the top-r left
                # singular directions; O(mnr), never materializes the m×m Gram
                z = jnp.einsum("...mn,...nr->...mr", x32,
                               jnp.einsum("...mn,...mr->...nr", x32, q_prev))
                cold = jnp.sum(jnp.square(q_prev), axis=(-2, -1),
                               keepdims=True) == 0.0
                z = jnp.where(cold, jnp.eye(m, r, dtype=jnp.float32), z)
                q = jnp.linalg.qr(z)[0]
                f = jnp.einsum("...mr,...mn->...rn", q, x32)
                o = gram_newton_schulz(f.astype(x.dtype), cfg=ns,
                                       assume_short_fat=True)
                u = jnp.einsum("...mr,...rn->...mn", q,
                               o.astype(jnp.float32))
                out[k] = (u * float(np.sqrt(m / r))).astype(x.dtype)
                new_q[k] = q
            return out, new_q

        out, new_q = layout.shard_local(run, {"stacks": stacks,
                                              "q": state["q"]})
        return out, {"q": new_q}


class BlockPeriodicGramNS(Orthogonalizer):
    """MuonBP-style block-periodic orthogonalization.

    Refresh steps (``step % cfg.muonbp_period == 0``) run the full Gram NS
    and cache the polar accumulator Q_k; in-between steps reuse the cached
    Q on the freshly normalized momentum — amortizing the 4k−3 symmetric
    products of the iteration down to a single m×n product per step."""

    name = "block_periodic"

    def init_state(self, layout, cfg):
        q = {group_key_str(k): layout.zeros(
                k, jnp.float32,
                trailing=(layout.plan.groups[k].key[0],) * 2)
             for k in layout.group_keys}
        return {"q": q}

    def __call__(self, stacks, *, step, state, layout, cfg):
        ns = cfg.ns
        cdtype = jnp.dtype(ns.compute_dtype)

        def do_refresh(operands):
            sts, _ = operands

            def run(sts_in):
                out, newq = {}, {}
                for k, x in sts_in.items():
                    x0, g = gram_prepare(x, ns)
                    q = gram_iterate(g, ns)
                    out[k] = gram_finish(q, x0, x.dtype)
                    newq[k] = q.astype(jnp.float32)
                return out, newq

            return layout.shard_local(run, sts)

        def do_reuse(operands):
            sts, qs = operands

            def run(args):
                sts_in, qs_in = args["stacks"], args["q"]
                out = {}
                for k, x in sts_in.items():
                    norm = jnp.sqrt(jnp.sum(
                        jnp.square(x.astype(jnp.float32)),
                        axis=(-2, -1), keepdims=True))
                    x0 = x.astype(cdtype) / (norm + _EPS).astype(cdtype)
                    out[k] = gram_finish(qs_in[k].astype(cdtype), x0, x.dtype)
                return out, qs_in

            return layout.shard_local(run, {"stacks": sts, "q": qs})

        period = max(1, int(cfg.muonbp_period))
        if period == 1:
            out, new_q = do_refresh((stacks, state["q"]))
        else:
            out, new_q = jax.lax.cond(step % period == 0, do_refresh,
                                      do_reuse, (stacks, state["q"]))
        return out, {"q": new_q}


ORTHOGONALIZERS = {
    "gram": GramNS,
    "gram_fused": BucketFusedGramNS,
    "full_ns": FullMatrixNS,
    "block_periodic": BlockPeriodicGramNS,
    "dion2": Dion2GramNS,
}

# wrappers composed over the base Gram path (plain or bucket-fused)
COMPOSED_ORTHOGONALIZERS = {
    "normuon": NeuronwiseNorm,
    "adamuon": AdaptiveSecondMoment,
}

# names resolving to the base Gram dispatch itself
BASE_ALIASES = ("auto", "gram_auto")


def known_orthogonalizers() -> list:
    """Every name ``make_orthogonalizer`` accepts — the single source of
    truth for registries, error messages, and tests."""
    return sorted(set(ORTHOGONALIZERS) | set(COMPOSED_ORTHOGONALIZERS)
                  | set(BASE_ALIASES))


def make_orthogonalizer(name: str, cfg) -> Orthogonalizer:
    """Build the backend for ``name``, honoring ``cfg.ns.bucket_fusion``.

    Composed names (``"normuon"``, ``"adamuon"``) wrap the base Gram path;
    ``"auto"``/``"gram_auto"`` are the plain DMuon dispatch (fused when
    configured)."""
    base = BucketFusedGramNS() if cfg.ns.bucket_fusion else GramNS()
    if name in BASE_ALIASES:
        return base
    if name in COMPOSED_ORTHOGONALIZERS:
        return COMPOSED_ORTHOGONALIZERS[name](base)
    try:
        return ORTHOGONALIZERS[name]()
    except KeyError:
        raise ValueError(
            f"unknown orthogonalizer {name!r}; "
            f"known: {known_orthogonalizers()}") from None
