"""The DMuon optimizer: owner-centric distributed Muon + baselines (§3.5, Alg. 1).

Optax-style gradient transformations with three execution modes:

* ``owner``  — DMuon.  Matrix gradients are packed into owner-major stacked
  buffers whose leading axis is sharded over the owner mesh axes (the SPMD
  realization of "reduce to the owner": XLA inserts the reduce-scatter /
  all-to-all).  Momentum lives permanently in this layout (owner-side
  authoritative state, fully sharded).  The batched Gram-NS runs on the local
  slice only — 1/D of the matrices per device — and the orthogonalized
  updates are published back to each parameter's training sharding (XLA:
  all-gather, overlapped by the scheduler).
* ``gather`` — Muon-AG baseline.  Gradients stay in training layout,
  momentum too; the full-matrix standard NS runs identically on every device
  (the replicated-compute cost the paper eliminates).
* ``adamw``  — element-wise baseline for step-time comparisons.

Non-matrix parameters always take AdamW (Alg. 1 line 16).  All modes produce
*identical* updates up to NS-iteration rounding — tests/test_muon.py + tests/dist_check.py check
owner == gather == per-matrix reference exactly.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.dedication import DedicationPlan
from repro.core.gram_ns import GramNSConfig, gram_newton_schulz
from repro.core.newton_schulz import newton_schulz


@dataclass(frozen=True)
class MuonConfig:
    learning_rate: float = 0.02
    momentum: float = 0.95
    nesterov: bool = True
    weight_decay: float = 0.0
    ns: GramNSConfig = GramNSConfig()
    # update scale: 'match_rms_adam' = 0.2·sqrt(max(m,n)) (Moonlight),
    # 'spectral' = sqrt(max(1, m/n)), 'none' = 1.0
    scale_mode: str = "match_rms_adam"
    mode: str = "owner"                  # 'owner' | 'gather' | 'adamw'
    momentum_dtype: str = "float32"
    # dtype of the packed owner-layout gradient/momentum math; bf16 for
    # trillion-param configs (memory policy, DESIGN.md §8)
    pack_dtype: str = "float32"
    # AdamW settings for non-matrix params (and for mode='adamw')
    adam_lr: float = 3e-4
    adam_b1: float = 0.9
    adam_b2: float = 0.95
    adam_eps: float = 1e-8
    adam_weight_decay: float = 0.0
    # gradient-transpose compression: reduce to owners in bf16 with fp32
    # error-feedback accumulator (distributed-optimization trick; DESIGN §7)
    compress_grads: bool = False


def _scale_factor(m: int, n: int, mode: str) -> float:
    if mode == "match_rms_adam":
        return 0.2 * float(np.sqrt(max(m, n)))
    if mode == "spectral":
        return float(np.sqrt(max(1.0, m / n)))
    if mode == "none":
        return 1.0
    raise ValueError(f"unknown scale_mode {mode!r}")


class AdamWState(NamedTuple):
    mu: Any
    nu: Any


def adamw_init(params) -> AdamWState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return AdamWState(mu=zeros,
                      nu=jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                      params))


def adamw_update(grads, state: AdamWState, params, step, cfg: MuonConfig):
    b1, b2, eps = cfg.adam_b1, cfg.adam_b2, cfg.adam_eps
    mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32),
                      state.mu, grads)
    nu = jax.tree.map(
        lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(jnp.float32)),
        state.nu, grads)
    t = step.astype(jnp.float32) + 1.0
    bc1 = 1.0 - b1 ** t
    bc2 = 1.0 - b2 ** t

    def upd(m, v, p):
        u = (m / bc1) / (jnp.sqrt(v / bc2) + eps)
        u = u + cfg.adam_weight_decay * p.astype(jnp.float32)
        return (-cfg.adam_lr * u).astype(p.dtype)

    updates = jax.tree.map(upd, mu, nu, params)
    return updates, AdamWState(mu, nu)


# --------------------------------------------------------------------------
# Owner-layout pack / unpack (the communication pattern of §3.2)
# --------------------------------------------------------------------------

def _lead_perm(info, spec) -> tuple:
    """Permutation of the leaf's leading dims putting sharded dims first
    (major).  Flattening a sharded-MAJOR axis keeps the merged-axis sharding
    expressible and every reshape local — the property that lets the owner
    transpose lower to one same-shape all-to-all instead of XLA's
    "involuntary full rematerialization" (whole-tensor all-gather)."""
    n_lead = len(info.shape) - 2
    if spec is None or n_lead <= 1:
        return tuple(range(n_lead))
    lead = list(spec)[:n_lead] if len(spec) >= n_lead else [None] * n_lead
    return tuple(sorted(range(n_lead), key=lambda i: (lead[i] is None, i)))


def _stacked_spec(info, spec):
    """Training-layout PartitionSpec of the (count, m, n) stacked view."""
    from jax.sharding import PartitionSpec as P
    if spec is None:
        return None
    n_lead = len(info.shape) - 2
    lead = list(spec)[:n_lead]
    perm = _lead_perm(info, spec)
    major = lead[perm[0]] if n_lead and perm and lead[perm[0]] is not None \
        else None
    m_spec = spec[-2] if len(spec) >= 2 else None
    n_spec = spec[-1] if len(spec) >= 1 else None
    if info.transpose:
        m_spec, n_spec = n_spec, m_spec
    return P(major, m_spec, n_spec)


def _leaf_to_matrices(arr: jax.Array, info, spec=None) -> jax.Array:
    """(lead..., m0, n0) -> (count, m, n) with m <= n, sharded-major order."""
    m0, n0 = info.shape[-2:]
    perm = _lead_perm(info, spec)
    n_lead = arr.ndim - 2
    if perm != tuple(range(n_lead)):
        arr = jnp.transpose(arr, perm + (n_lead, n_lead + 1))
    flat = arr.reshape((-1, m0, n0))
    return flat.mT if info.transpose else flat


def _matrices_to_leaf(flat: jax.Array, info, spec=None) -> jax.Array:
    if info.transpose:
        flat = flat.mT
    perm = _lead_perm(info, spec)
    n_lead = len(info.shape) - 2
    if perm != tuple(range(n_lead)):
        permuted_shape = tuple(info.shape[i] for i in perm) + info.shape[-2:]
        inv = tuple(np.argsort(perm)) + (n_lead, n_lead + 1)
        return jnp.transpose(flat.reshape(permuted_shape), inv)
    return flat.reshape(info.shape)


def pack_group(plan: DedicationPlan, key, leaf_values: Dict[str, jax.Array],
               mesh=None) -> jax.Array:
    """Stack a shape group's matrices into the owner-major padded layout.

    Output: (num_owners * capacity, m, n); position p belongs to owner
    p // capacity.  With known training specs the stacked view is explicitly
    constrained so the only communication is the same-shape axis-0
    redistribution applied afterwards by the owner constraint.
    """
    g = plan.groups[key]
    specs = getattr(plan, "train_specs", None) or {}
    parts = []
    for p in g.leaf_paths:
        spec = specs.get(p)
        part = _leaf_to_matrices(leaf_values[p], plan.leaves[p], spec)
        st_spec = _stacked_spec(plan.leaves[p], spec)
        if mesh is not None and st_spec is not None:
            from jax.sharding import NamedSharding
            part = jax.lax.with_sharding_constraint(
                part, NamedSharding(mesh, st_spec))
        parts.append(part)
    flat = parts[0] if len(parts) == 1 else jnp.concatenate(parts, axis=0)
    m, n = g.key
    n_pad = g.packed_size - g.count
    if np.array_equal(g.pack_index[:g.count], np.arange(g.count)):
        # contiguous physical layout: pure pad — partitions as a local op
        if n_pad == 0:
            return flat
        return jnp.concatenate(
            [flat, jnp.zeros((n_pad, m, n), flat.dtype)], axis=0)
    pad = jnp.zeros((1, m, n), flat.dtype)
    flat_ext = jnp.concatenate([flat, pad], axis=0)
    idx = np.where(g.pack_index < 0, g.count, g.pack_index)
    return jnp.take(flat_ext, jnp.asarray(idx), axis=0)


def unpack_group(plan: DedicationPlan, key, packed: jax.Array,
                 mesh=None) -> Dict[str, jax.Array]:
    """Inverse of pack_group: owner-major stack -> per-leaf arrays.

    The publish reshard (owner layout -> training layout) happens HERE at the
    padded stacked shape — a same-shape axis redistribution (all-to-all) —
    before any slice/transpose/reshape, all of which are then sharding-local.
    """
    g = plan.groups[key]
    specs = getattr(plan, "train_specs", None) or {}
    if len(g.leaf_paths) == 1 and mesh is not None:
        p = g.leaf_paths[0]
        st_spec = _stacked_spec(plan.leaves[p], specs.get(p))
        if st_spec is not None:
            packed = _from_owner_staged(packed, st_spec, plan, mesh)
    if np.array_equal(g.unpack_index, np.arange(g.count)):
        flat = packed[:g.count]            # contiguous layout: pure slice
    else:
        flat = jnp.take(packed, jnp.asarray(g.unpack_index), axis=0)
    out: Dict[str, jax.Array] = {}
    start = 0
    for p in g.leaf_paths:
        info = plan.leaves[p]
        out[p] = _matrices_to_leaf(flat[start:start + info.count], info,
                                   specs.get(p))
        start += info.count
    return out


def owner_sharding(plan: DedicationPlan, mesh):
    """NamedSharding for the stacked owner-major buffers (axis 0 sharded)."""
    if mesh is None:
        return None
    from jax.sharding import NamedSharding, PartitionSpec as P
    axes = plan.owner_axes or tuple(mesh.axis_names)
    return NamedSharding(mesh, P(axes, None, None))


def _constrain(x, sharding):
    if sharding is None:
        return x
    return jax.lax.with_sharding_constraint(x, sharding)


def _to_owner_staged(x, stacked_spec, plan, mesh):
    """Training-stacked layout -> owner layout, one mesh axis per stage.

    Each stage moves a single mesh axis from a matrix dim onto the stack
    axis — a reshard GSPMD lowers as a true all-to-all.  Jumping directly to
    the owner spec lets XLA resolve the two-axis move "through replication"
    (full-tensor all-gathers), a TB-scale temp at 340B+ scale; see
    EXPERIMENTS.md §Perf (nemotron train iteration).
    """
    if mesh is None:
        return x
    from jax.sharding import NamedSharding, PartitionSpec as P
    axes = plan.owner_axes or tuple(mesh.axis_names)
    cur = list(stacked_spec) if stacked_spec is not None else [None] * 3
    while len(cur) < 3:
        cur.append(None)
    front = list(cur[0]) if isinstance(cur[0], tuple) else \
        ([cur[0]] if cur[0] is not None else [])
    for ax in axes:
        if ax in front:
            continue
        rest = [None if d == ax else d for d in cur[1:]]
        front = front + [ax]
        cur = [tuple(front)] + rest
        x = jax.lax.with_sharding_constraint(
            x, NamedSharding(mesh, P(*cur)))
    return x


def _from_owner_staged(x, stacked_spec, plan, mesh):
    """Owner layout -> training-stacked layout (publish), staged in reverse:
    one axis leaves the stack dim per stage (an all-to-all back to its matrix
    dim, or an all-gather when the training layout doesn't use it)."""
    if mesh is None:
        return x
    from jax.sharding import NamedSharding, PartitionSpec as P
    axes = list(plan.owner_axes or tuple(mesh.axis_names))
    target = list(stacked_spec) if stacked_spec is not None else [None] * 3
    while len(target) < 3:
        target.append(None)
    front = list(axes)
    rest = [None, None]
    for ax in reversed(axes):
        front = [a for a in front if a != ax]
        for di in (1, 2):
            if target[di] == ax:
                rest[di - 1] = ax
        lead = tuple(front) if front else target[0]
        x = jax.lax.with_sharding_constraint(
            x, NamedSharding(mesh, P(lead, rest[0], rest[1])))
    return x


# --------------------------------------------------------------------------
# The Muon update
# --------------------------------------------------------------------------

class MuonState(NamedTuple):
    step: jax.Array
    # mode='owner': {group_key_str: (D*cap, m, n) owner-major momentum}
    # mode='gather': momentum pytree in training layout (matrix leaves only)
    momentum: Any
    adamw: AdamWState            # state for non-matrix leaves
    error_feedback: Any = None   # fp32 residual for compressed grad transpose


def _group_key_str(key) -> str:
    return key.replace("/", ".") if isinstance(key, str) else f"{key[0]}x{key[1]}"


def _matrix_and_rest(plan: DedicationPlan, tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    treedef = jax.tree_util.tree_structure(tree)
    from repro.core.dedication import _key_str
    matrix, rest = {}, {}
    for kp, leaf in flat:
        path = "/".join(_key_str(k) for k in kp)
        (matrix if path in plan.leaves else rest)[path] = leaf
    return matrix, rest, treedef


def _rebuild(tree_like, matrix: Dict[str, Any], rest: Dict[str, Any]):
    """Reassemble a pytree of the same structure from the two path dicts."""
    from repro.core.dedication import _key_str
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree_like)
    leaves = []
    for kp, _ in flat:
        path = "/".join(_key_str(k) for k in kp)
        leaves.append(matrix[path] if path in matrix else rest[path])
    return jax.tree_util.tree_unflatten(treedef, leaves)


def muon_init(plan: DedicationPlan, params, cfg: MuonConfig, mesh=None
              ) -> MuonState:
    matrix, rest, _ = _matrix_and_rest(plan, params)
    mdt = jnp.dtype(cfg.momentum_dtype)
    if cfg.mode == "owner":
        shard = owner_sharding(plan, mesh)
        momentum = {}
        for key, g in plan.groups.items():
            m, n = g.key
            buf = jnp.zeros((g.packed_size, m, n), mdt)
            momentum[_group_key_str(key)] = _constrain(buf, shard)
    elif cfg.mode == "gather":
        momentum = {p: jnp.zeros(v.shape, mdt) for p, v in matrix.items()}
    else:  # adamw for everything
        momentum = {}
        rest = {**rest, **matrix}
    ef = None
    if cfg.compress_grads and cfg.mode == "owner":
        ef = {p: jnp.zeros(v.shape, jnp.float32) for p, v in matrix.items()}
    return MuonState(step=jnp.zeros((), jnp.int32), momentum=momentum,
                     adamw=adamw_init(rest), error_feedback=ef)


def muon_update(plan: DedicationPlan, grads, state: MuonState, params,
                cfg: MuonConfig, mesh=None):
    """One optimizer step. Returns (updates, new_state); updates are deltas
    to be *added* to params (optax convention)."""
    gm, gr, _ = _matrix_and_rest(plan, grads)
    pm, pr, _ = _matrix_and_rest(plan, params)

    if cfg.mode == "adamw":
        gr, pr = {**gr, **gm}, {**pr, **pm}
        adam_updates, adamw_state = adamw_update(gr, state.adamw, pr,
                                                 state.step, cfg)
        updates = _rebuild(grads, {}, adam_updates)
        return updates, MuonState(state.step + 1, state.momentum, adamw_state,
                                  state.error_feedback)

    adam_updates, adamw_state = adamw_update(gr, state.adamw, pr, state.step,
                                             cfg)

    if cfg.mode == "owner":
        matrix_updates, new_momentum, new_ef = _owner_update(
            plan, gm, pm, state, cfg, mesh)
    elif cfg.mode == "gather":
        matrix_updates, new_momentum = _gather_update(plan, gm, pm, state, cfg)
        new_ef = state.error_feedback
    else:
        raise ValueError(f"unknown mode {cfg.mode!r}")

    updates = _rebuild(grads, matrix_updates, adam_updates)
    return updates, MuonState(state.step + 1, new_momentum, adamw_state,
                              new_ef)


def _apply_wd_and_lr(update, param, cfg: MuonConfig):
    # fp32 update math when the master params are fp32; for bf16-master
    # configs (DESIGN.md §8) stay in bf16 — the fp32 temp would be the
    # largest buffer in the program.
    cd = jnp.float32 if param.dtype == jnp.float32 else param.dtype
    u = update.astype(cd) + cfg.weight_decay * param.astype(cd)
    return (-cfg.learning_rate * u).astype(param.dtype)


def _owner_update(plan: DedicationPlan, gm, pm, state: MuonState,
                  cfg: MuonConfig, mesh):
    """DMuon path: pack → momentum → batched Gram NS (per Gram bucket) →
    unpack/publish.  Alg. 1 lines 10–15 in SPMD form."""
    shard = owner_sharding(plan, mesh)
    new_momentum: Dict[str, jax.Array] = {}
    new_ef = state.error_feedback

    # --- gradient routing: training layout -> owner layout (reduce-to-owner)
    grads_for_pack = gm
    if cfg.compress_grads and state.error_feedback is not None:
        # bf16 transpose with fp32 error feedback: compressed = bf16(g + e);
        # residual e' = (g + e) - compressed stays in training layout.
        compressed, new_ef = {}, {}
        for p, g in gm.items():
            acc = g.astype(jnp.float32) + state.error_feedback[p]
            cg = acc.astype(jnp.bfloat16)
            new_ef[p] = acc - cg.astype(jnp.float32)
            compressed[p] = cg
        grads_for_pack = compressed

    pdt = jnp.dtype(cfg.pack_dtype)
    specs = getattr(plan, "train_specs", None) or {}
    packed_mom: Dict[Any, jax.Array] = {}
    for key in plan.groups:
        g = plan.groups[key]
        g_packed = pack_group(plan, key, {
            p: grads_for_pack[p] for p in g.leaf_paths}, mesh=mesh)
        st_spec = (_stacked_spec(plan.leaves[g.leaf_paths[0]],
                                 specs.get(g.leaf_paths[0]))
                   if len(g.leaf_paths) == 1 else None)
        g_packed = _to_owner_staged(g_packed.astype(pdt), st_spec, plan, mesh)
        g_packed = _constrain(g_packed, shard)
        mom = state.momentum[_group_key_str(key)].astype(pdt)
        mom = cfg.momentum * mom + g_packed
        new_momentum[_group_key_str(key)] = _constrain(
            mom.astype(jnp.dtype(cfg.momentum_dtype)), shard)
        eff = g_packed + cfg.momentum * mom if cfg.nesterov else mom
        packed_mom[key] = _constrain(eff, shard)

    # --- owner-side batched Gram NS.  With bucket_fusion the m×m iteration
    # phase is batched across all groups sharing a Gram dimension (paper
    # §3.3 shape-batched execution at its widest); otherwise per-group.
    ortho: Dict[Any, jax.Array] = {}
    if cfg.ns.bucket_fusion:
        ortho = _sharded_gram_ns_fused(packed_mom, cfg.ns, mesh, plan)
    else:
        for key in plan.groups:
            ortho[key] = _sharded_gram_ns(packed_mom[key], cfg.ns, mesh,
                                          plan)

    # --- publication: owner layout -> training layout + scale/wd/lr.
    # The resharded tensor stays in pack_dtype; fp32 casting before the
    # all-to-all would double the publish volume (and at 1T scale the fp32
    # temp alone exceeds HBM).
    matrix_updates: Dict[str, jax.Array] = {}
    for key in plan.groups:
        m, n = plan.groups[key].key
        s = _scale_factor(m, n, cfg.scale_mode)
        per_leaf = unpack_group(plan, key, ortho[key].astype(pdt) * s,
                                mesh=mesh)
        for p, upd in per_leaf.items():
            matrix_updates[p] = _apply_wd_and_lr(upd, pm[p], cfg)
    return matrix_updates, new_momentum, new_ef


def _sharded_gram_ns(packed: jax.Array, ns_cfg: GramNSConfig, mesh,
                     plan: DedicationPlan) -> jax.Array:
    """Run batched Gram NS on the owner-sharded stack.

    Under a mesh, shard_map with P(owner_axes) on the stack axis makes the
    computation provably local (no collectives inside); each device
    orthogonalizes only its own matrices.  Without a mesh (unit tests), plain
    batched execution.
    """
    base = functools.partial(gram_newton_schulz, cfg=ns_cfg,
                             assume_short_fat=True)

    def fn(x):
        if ns_cfg.owner_chunk and x.shape[0] > ns_cfg.owner_chunk \
                and x.shape[0] % ns_cfg.owner_chunk == 0:
            # bound the live Gram working set: sequential chunks of the
            # owner-local batch (memory policy for 1T-class censuses)
            xc = x.reshape((-1, ns_cfg.owner_chunk) + x.shape[1:])
            return jax.lax.map(base, xc).reshape(x.shape)
        return base(x)

    if mesh is None:
        return fn(packed)
    from jax.sharding import PartitionSpec as P
    axes = plan.owner_axes or tuple(mesh.axis_names)
    spec = P(axes, None, None)
    return jax.shard_map(fn, mesh=mesh, in_specs=(spec,), out_specs=spec)(
        packed)


def _sharded_gram_ns_fused(packed: Dict[Any, jax.Array],
                           ns_cfg: GramNSConfig, mesh,
                           plan: DedicationPlan) -> Dict[Any, jax.Array]:
    """Bucket-fused owner NS: one batched m×m recurrence per Gram bucket.

    Phases (core/gram_ns.py): per-group prepare (normalize + SYRK, shapes
    differ in n), concat the Gram stacks of every group in the bucket,
    ONE batched iterate, split Q back, per-group finish (Q·X₀).  All inside
    a single shard_map so the whole optimizer phase is one local region."""
    import functools as _ft

    from repro.core.gram_ns import gram_finish, gram_iterate, gram_prepare

    def run(stacks: Dict[str, jax.Array]) -> Dict[str, jax.Array]:
        out: Dict[str, jax.Array] = {}
        for m_dim, keys in plan.buckets.items():
            keys_here = [k for k in keys if k in stacks]
            if not keys_here:
                continue
            x0s, gs, sizes = [], [], []
            for k in keys_here:
                x0, g = gram_prepare(stacks[k], ns_cfg)
                x0s.append(x0)
                gs.append(g)
                sizes.append(g.shape[0])
            q_all = gram_iterate(jnp.concatenate(gs, axis=0), ns_cfg)
            off = 0
            for k, x0, sz in zip(keys_here, x0s, sizes):
                out[k] = gram_finish(q_all[off:off + sz], x0,
                                     stacks[k].dtype)
                off += sz
        return out

    if mesh is None:
        return run(packed)
    from jax.sharding import PartitionSpec as P
    axes = plan.owner_axes or tuple(mesh.axis_names)
    spec = P(axes, None, None)
    in_specs = ({k: spec for k in packed},)
    out_specs = {k: spec for k in packed}
    return jax.shard_map(run, mesh=mesh, in_specs=in_specs,
                         out_specs=out_specs)(packed)


def _gather_update(plan: DedicationPlan, gm, pm, state: MuonState,
                   cfg: MuonConfig):
    """Muon-AG baseline: momentum in training layout; full-matrix standard NS
    computed redundantly on every device (SPMD: replicated compute)."""
    new_momentum: Dict[str, jax.Array] = {}
    matrix_updates: Dict[str, jax.Array] = {}
    for p, g in gm.items():
        info = plan.leaves[p]
        g32 = g.astype(jnp.float32)
        mom = cfg.momentum * state.momentum[p].astype(jnp.float32) + g32
        new_momentum[p] = mom.astype(jnp.dtype(cfg.momentum_dtype))
        eff = g32 + cfg.momentum * mom if cfg.nesterov else mom
        o = newton_schulz(eff, num_steps=cfg.ns.num_steps,
                          schedule=cfg.ns.schedule)
        m, n = info.group
        s = _scale_factor(m, n, cfg.scale_mode)
        matrix_updates[p] = _apply_wd_and_lr(o * s, pm[p], cfg)
    return matrix_updates, new_momentum
