"""The DMuon optimizer orchestrator: layout → orthogonalize → update rule.

This module is the thin composition point of the three optimizer layers:

* ``core/owner_comms.py``    — WHERE matrices live: the owner-major packed
  layout, the staged all-to-all resharding, the owner sharding (§3.2).
* ``core/orthogonalize.py``  — HOW updates are orthogonalized: batched Gram
  NS, bucket-fused NS, full-matrix NS, and the NorMuon / MuonBP variant
  backends, all behind one protocol.
* ``core/update_rules.py``   — WHAT scalar math wraps them: momentum,
  RMS-matching scale, weight decay / lr, and elementwise AdamW.

Execution modes (``MuonConfig.mode``):

* ``owner``  — DMuon.  Matrix gradients are packed into owner-major stacked
  buffers whose leading axis is sharded over the owner mesh axes (the SPMD
  realization of "reduce to the owner").  Momentum lives permanently in this
  layout; the orthogonalizer runs on the local slice only and the updates
  are published back to each parameter's training sharding.
* ``gather`` — Muon-AG baseline: momentum in training layout, full-matrix NS
  replicated on every device.
* ``adamw``  — element-wise baseline for step-time comparisons.

Variants (``MuonConfig.variant``; registry in ``core/api.py``): ``muon``,
``normuon``, ``muonbp``, ``dion2``, ``adamuon``, ``adamw`` — all sharing the
owner-layout pipeline, differing only in the orthogonalizer backend (and its
per-group state, threaded through ``MuonState.variant_state``).

Non-matrix parameters always take AdamW (Alg. 1 line 16).  All modes produce
*identical* updates up to NS-iteration rounding for variant='muon' —
tests/test_muon.py + tests/dist_check.py check owner == gather == per-matrix
reference exactly.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, NamedTuple

import jax
import jax.numpy as jnp

from repro.core.dedication import DedicationPlan
from repro.core.gram_ns import GramNSConfig
from repro.core.orthogonalize import make_orthogonalizer
from repro.core.owner_comms import (  # noqa: F401 — stable re-exports
    OwnerLayout, _from_owner_staged, _lead_perm, _stacked_spec,
    _to_owner_staged, group_key_str, owner_sharding, pack_group, unpack_group)
from repro.core.update_rules import (  # noqa: F401 — stable re-exports
    AdamWState, adamw_init, adamw_update, apply_wd_and_lr, momentum_update,
    scale_factor)

# Backwards-compatible aliases (pre-refactor private names).
_group_key_str = group_key_str
_scale_factor = scale_factor
_apply_wd_and_lr = apply_wd_and_lr


@dataclass(frozen=True)
class MuonConfig:
    learning_rate: float = 0.02
    momentum: float = 0.95
    nesterov: bool = True
    weight_decay: float = 0.0
    ns: GramNSConfig = GramNSConfig()
    # update scale: 'match_rms_adam' = 0.2·sqrt(max(m,n)) (Moonlight),
    # 'spectral' = sqrt(max(1, m/n)), 'none' = 1.0
    scale_mode: str = "match_rms_adam"
    mode: str = "owner"                  # 'owner' | 'gather' | 'adamw'
    # optimizer variant by name (registry in core/api.py):
    #   'muon'    — plain orthogonalized updates (the paper's optimizer)
    #   'normuon' — + neuron-wise second-moment normalization (NorMuon)
    #   'muonbp'  — block-periodic NS refresh every `muonbp_period` steps
    #   'dion2'   — Gram NS on a warm-started rank-r factor only (Dion2)
    #   'adamuon' — + elementwise second-moment adaptation (AdaMuon)
    #   'adamw'   — elementwise baseline (equivalent to mode='adamw')
    variant: str = "muon"
    # optimizer-step schedule for mode='owner' (core/pipeline.py):
    #   'fused'    — one post-backward phase: pack all → NS all → publish all
    #   'bucketed' — per-Gram-bucket stage_in/compute/publish pipeline with
    #                double-buffered staging (bit-exact with 'fused';
    #                docs/DESIGN.md §6)
    pipeline: str = "fused"
    # keep the bucketed schedule's optimization_barrier ties (disable to let
    # XLA schedule freely — changes overlap/memory, never values)
    pipeline_barriers: bool = True
    # pre-warm the kernel autotune cache for every shape in the dedication
    # plan at optimizer construction (paper §3.3 workflow)
    autotune_prewarm: bool = True
    momentum_dtype: str = "float32"
    # dtype of the packed owner-layout gradient/momentum math; bf16 for
    # trillion-param configs (memory policy, docs/DESIGN.md §8)
    pack_dtype: str = "float32"
    # AdamW settings for non-matrix params (and for mode='adamw')
    adam_lr: float = 3e-4
    adam_b1: float = 0.9
    adam_b2: float = 0.95
    adam_eps: float = 1e-8
    adam_weight_decay: float = 0.0
    # gradient-transpose compression: reduce to owners in bf16 with fp32
    # error-feedback accumulator (docs/DESIGN.md §7)
    compress_grads: bool = False
    # variant knobs
    normuon_beta2: float = 0.95          # NorMuon neuron second-moment decay
    normuon_eps: float = 1e-8
    muonbp_period: int = 4               # full-NS refresh period (1 = every step)
    # Dion2: rank fraction r/m of the shrunken factor the Gram NS runs on
    # (1.0 = full-rank; the update then matches plain muon up to rounding)
    dion2_rank_frac: float = 0.25
    adamuon_beta2: float = 0.95          # AdaMuon entry second-moment decay
    adamuon_eps: float = 1e-8


def _resolve(cfg: MuonConfig):
    """(variant_spec, effective_mode) for ``cfg`` — validates the combo."""
    from repro.core.api import get_variant   # lazy: api imports this module
    spec = get_variant(cfg.variant)
    mode = "adamw" if spec.elementwise else cfg.mode
    if cfg.mode == "gather" and not spec.elementwise and spec.name != "muon":
        raise ValueError(
            f"variant {spec.name!r} requires the owner pipeline "
            "(mode='owner'); the gather baseline only supports 'muon'")
    if cfg.pipeline not in ("fused", "bucketed"):
        raise ValueError(f"unknown pipeline {cfg.pipeline!r}; "
                         "known: 'fused', 'bucketed'")
    if cfg.pipeline == "bucketed" and mode == "gather":
        raise ValueError(
            "pipeline='bucketed' schedules the owner-layout stages; the "
            "gather baseline has no staged comms to pipeline (mode='owner')")
    return spec, mode


def compress_with_error_feedback(gm, error_feedback, cfg: MuonConfig):
    """bf16 gradient transpose with fp32 error feedback (docs/DESIGN.md §7):
    compressed = bf16(g + e); residual e' = (g + e) - compressed stays in the
    training layout.  Identity when compression is off.  Returns
    ``(grads_for_pack, new_error_feedback)``."""
    if not (cfg.compress_grads and error_feedback is not None):
        return gm, error_feedback
    compressed, new_ef = {}, {}
    for p, g in gm.items():
        acc = g.astype(jnp.float32) + error_feedback[p]
        cg = acc.astype(jnp.bfloat16)
        new_ef[p] = acc - cg.astype(jnp.float32)
        compressed[p] = cg
    return compressed, new_ef


# --------------------------------------------------------------------------
# Optimizer state
# --------------------------------------------------------------------------

class MuonState(NamedTuple):
    step: jax.Array
    # mode='owner': {group_key_str: (D*cap, m, n) owner-major momentum}
    # mode='gather': momentum pytree in training layout (matrix leaves only)
    momentum: Any
    adamw: AdamWState            # state for non-matrix leaves
    error_feedback: Any = None   # fp32 residual for compressed grad transpose
    # per-variant orthogonalizer state (owner-major buffers), e.g. NorMuon's
    # neuron-wise second moments or MuonBP's cached polar accumulators
    variant_state: Any = None


def _matrix_and_rest(plan: DedicationPlan, tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    treedef = jax.tree_util.tree_structure(tree)
    from repro.core.dedication import _key_str
    matrix, rest = {}, {}
    for kp, leaf in flat:
        path = "/".join(_key_str(k) for k in kp)
        (matrix if path in plan.leaves else rest)[path] = leaf
    return matrix, rest, treedef


def _rebuild(tree_like, matrix: Dict[str, Any], rest: Dict[str, Any]):
    """Reassemble a pytree of the same structure from the two path dicts."""
    from repro.core.dedication import _key_str
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree_like)
    leaves = []
    for kp, _ in flat:
        path = "/".join(_key_str(k) for k in kp)
        leaves.append(matrix[path] if path in matrix else rest[path])
    return jax.tree_util.tree_unflatten(treedef, leaves)


def muon_init(plan: DedicationPlan, params, cfg: MuonConfig, mesh=None
              ) -> MuonState:
    matrix, rest, _ = _matrix_and_rest(plan, params)
    spec, mode = _resolve(cfg)
    layout = OwnerLayout(plan, mesh)
    mdt = jnp.dtype(cfg.momentum_dtype)
    variant_state = None
    if mode == "owner":
        momentum = {group_key_str(key): layout.zeros(key, mdt)
                    for key in plan.groups}
        if spec.stateful:
            ortho = make_orthogonalizer(spec.orthogonalizer, cfg)
            variant_state = ortho.init_state(layout, cfg)
    elif mode == "gather":
        momentum = {p: jnp.zeros(v.shape, mdt) for p, v in matrix.items()}
    else:  # adamw for everything
        momentum = {}
        rest = {**rest, **matrix}
    ef = None
    if cfg.compress_grads and mode == "owner":
        ef = {p: jnp.zeros(v.shape, jnp.float32) for p, v in matrix.items()}
    return MuonState(step=jnp.zeros((), jnp.int32), momentum=momentum,
                     adamw=adamw_init(rest), error_feedback=ef,
                     variant_state=variant_state)


def muon_update(plan: DedicationPlan, grads, state: MuonState, params,
                cfg: MuonConfig, mesh=None):
    """One optimizer step. Returns (updates, new_state); updates are deltas
    to be *added* to params (optax convention)."""
    gm, gr, _ = _matrix_and_rest(plan, grads)
    pm, pr, _ = _matrix_and_rest(plan, params)
    spec, mode = _resolve(cfg)

    if mode == "adamw":
        gr, pr = {**gr, **gm}, {**pr, **pm}
        adam_updates, adamw_state = adamw_update(gr, state.adamw, pr,
                                                 state.step, cfg)
        updates = _rebuild(grads, {}, adam_updates)
        return updates, MuonState(state.step + 1, state.momentum, adamw_state,
                                  state.error_feedback, state.variant_state)

    adam_updates, adamw_state = adamw_update(gr, state.adamw, pr, state.step,
                                             cfg)

    if mode == "owner":
        matrix_updates, new_momentum, new_ef, new_vstate = _owner_update(
            plan, gm, pm, state, cfg, mesh, spec)
    elif mode == "gather":
        matrix_updates, new_momentum = _gather_update(plan, gm, pm, state,
                                                      cfg, mesh)
        new_ef, new_vstate = state.error_feedback, state.variant_state
    else:
        raise ValueError(f"unknown mode {cfg.mode!r}")

    updates = _rebuild(grads, matrix_updates, adam_updates)
    return updates, MuonState(state.step + 1, new_momentum, adamw_state,
                              new_ef, new_vstate)


def _owner_update(plan: DedicationPlan, gm, pm, state: MuonState,
                  cfg: MuonConfig, mesh, spec):
    """DMuon path: pack → momentum → orthogonalize (pluggable backend) →
    unpack/publish.  Alg. 1 lines 10–15 in SPMD form.

    ``cfg.pipeline`` selects the schedule: 'fused' is the one-phase
    post-backward program below; 'bucketed' delegates to the per-Gram-bucket
    stage_in/compute/publish pipeline (core/pipeline.py) — same math, ordered
    so the staged comms overlap the compute wavefront."""
    if cfg.pipeline == "bucketed":
        from repro.core.pipeline import BucketPipeline
        pipe = BucketPipeline(plan, cfg, mesh, spec)
        return pipe.run_from_grads(gm, pm, state)

    layout = OwnerLayout(plan, mesh)
    new_momentum: Dict[str, jax.Array] = {}

    # --- gradient routing: training layout -> owner layout (reduce-to-owner)
    grads_for_pack, new_ef = compress_with_error_feedback(
        gm, state.error_feedback, cfg)

    pdt = jnp.dtype(cfg.pack_dtype)
    packed_mom: Dict[str, jax.Array] = {}
    skey_to_key = {group_key_str(key): key for key in plan.groups}
    for key, g in plan.groups.items():
        g_packed = layout.pack(key, {p: grads_for_pack[p].astype(pdt)
                                     for p in g.leaf_paths})
        skey = group_key_str(key)
        mom = state.momentum[skey].astype(pdt)
        mom, eff = momentum_update(mom, g_packed, cfg)
        new_momentum[skey] = layout.constrain(
            mom.astype(jnp.dtype(cfg.momentum_dtype)))
        packed_mom[skey] = layout.constrain(eff)

    # --- owner-side orthogonalization via the variant's pluggable backend
    # (batched Gram NS by default; bucket-fused / NorMuon / MuonBP by name).
    ortho_fn = make_orthogonalizer(spec.orthogonalizer, cfg)
    ortho, new_vstate = ortho_fn(packed_mom, step=state.step,
                                 state=state.variant_state, layout=layout,
                                 cfg=cfg)

    # --- publication: owner layout -> training layout + scale/wd/lr.
    # The resharded tensor stays in pack_dtype; fp32 casting before the
    # all-to-all would double the publish volume (and at 1T scale the fp32
    # temp alone exceeds HBM).
    matrix_updates: Dict[str, jax.Array] = {}
    for skey, o in ortho.items():
        key = skey_to_key[skey]
        m, n = plan.groups[key].key
        s = scale_factor(m, n, cfg.scale_mode)
        per_leaf = layout.unpack(key, o.astype(pdt) * s)
        for p, upd in per_leaf.items():
            matrix_updates[p] = apply_wd_and_lr(upd, pm[p], cfg)
    return matrix_updates, new_momentum, new_ef, new_vstate


def muon_update_staged(plan: DedicationPlan, staged, rest_grads,
                       state: MuonState, params, cfg: MuonConfig, mesh=None):
    """One optimizer step from PRE-STAGED owner-layout matrix gradients.

    ``staged`` is {group_key_str: (D·cap, m, n) owner-major gradient stack}
    (already averaged over microbatches); ``rest_grads`` is the {path: grad}
    dict of the non-matrix (AdamW) leaves.  This is the entry point of the
    accumulation-overlapped bucketed pipeline: ``train/step.py`` packs each
    microbatch's gradients to owners inside the ``lax.scan`` (stage_in under
    the backward pass), then calls this to run compute + publish only.

    Bit-exact with ``muon_update`` on the packed-then-averaged gradients:
    packing is a permutation + zero-pad, so it commutes with the microbatch
    sum, the 1/accum scaling, and the pack-dtype cast.

    Incompatible with ``compress_grads`` (error feedback needs the summed
    gradient in the training layout) — callers fall back to the unstaged
    path; enforced here.
    """
    spec, mode = _resolve(cfg)
    if mode != "owner":
        raise ValueError(f"muon_update_staged requires mode='owner' "
                         f"(got {mode!r})")
    if cfg.compress_grads:
        raise ValueError("pre-staged gradients are incompatible with "
                         "compress_grads (error feedback is a training-layout "
                         "residual)")
    pm, pr, _ = _matrix_and_rest(plan, params)
    adam_updates, adamw_state = adamw_update(rest_grads, state.adamw, pr,
                                             state.step, cfg)
    from repro.core.pipeline import BucketPipeline
    pipe = BucketPipeline(plan, cfg, mesh, spec)
    matrix_updates, new_momentum, new_vstate = pipe.run_staged(
        staged, pm, state)
    updates = _rebuild(params, matrix_updates, adam_updates)
    return updates, MuonState(state.step + 1, new_momentum, adamw_state,
                              state.error_feedback, new_vstate)


def _gather_update(plan: DedicationPlan, gm, pm, state: MuonState,
                   cfg: MuonConfig, mesh=None):
    """Muon-AG baseline: momentum in training layout; full-matrix standard NS
    computed redundantly on every device (SPMD: replicated compute)."""
    from repro.core.orthogonalize import FullMatrixNS
    layout = OwnerLayout(plan, mesh)
    new_momentum: Dict[str, jax.Array] = {}
    eff_all: Dict[str, jax.Array] = {}
    for p, g in gm.items():
        g32 = g.astype(jnp.float32)
        mom, eff = momentum_update(state.momentum[p].astype(jnp.float32),
                                   g32, cfg)
        new_momentum[p] = mom.astype(jnp.dtype(cfg.momentum_dtype))
        eff_all[p] = eff
    ortho, _ = FullMatrixNS()(eff_all, step=state.step, state=None,
                              layout=layout, cfg=cfg)
    matrix_updates: Dict[str, jax.Array] = {}
    for p, o in ortho.items():
        m, n = plan.leaves[p].group
        s = scale_factor(m, n, cfg.scale_mode)
        matrix_updates[p] = apply_wd_and_lr(o * s, pm[p], cfg)
    return matrix_updates, new_momentum
