"""Update rules: momentum, scaling, weight decay, AdamW — the third layer.

``core/owner_comms.py`` decides where tensors live, ``core/orthogonalize.py``
decides how a matrix update is orthogonalized, and this module decides what
scalar math wraps those matrices: the heavy-ball/Nesterov momentum applied in
owner layout, the RMS-matching scale factor, weight decay + learning rate,
and the elementwise AdamW used for non-matrix leaves (and for the pure-AdamW
baseline variant).

``VariantSpec`` describes a named optimizer variant (the registry itself is
the user surface and lives in ``core/api.py``): which orthogonalizer backend
the owner pipeline dispatches to, and whether the variant bypasses the matrix
pipeline entirely (AdamW).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class VariantSpec:
    """A named optimizer variant, resolved by ``MuonConfig.variant``."""
    name: str
    orthogonalizer: str         # registry key in core/orthogonalize.py
    description: str = ""
    elementwise: bool = False   # True: no matrix pipeline at all (AdamW)
    stateful: bool = False      # carries per-group variant state


def scale_factor(m: int, n: int, mode: str) -> float:
    if mode == "match_rms_adam":
        return 0.2 * float(np.sqrt(max(m, n)))
    if mode == "spectral":
        return float(np.sqrt(max(1.0, m / n)))
    if mode == "none":
        return 1.0
    raise ValueError(f"unknown scale_mode {mode!r}")


def momentum_update(mom: jax.Array, grad: jax.Array, cfg):
    """Heavy-ball momentum in the layout of its inputs.

    Returns ``(new_momentum, effective)`` where ``effective`` is what the
    orthogonalizer consumes (the Nesterov look-ahead when configured)."""
    new_mom = cfg.momentum * mom + grad
    eff = grad + cfg.momentum * new_mom if cfg.nesterov else new_mom
    return new_mom, eff


def norm_preserving_rescale(normalized: jax.Array, reference: jax.Array,
                            eps: float = 1e-7) -> jax.Array:
    """Rescale each matrix in ``normalized`` back to the Frobenius norm of
    its ``reference`` counterpart (leading dims are batch).

    Adaptive variants (NorMuon's per-neuron, AdaMuon's per-entry second
    moments) reshape the orthogonalized update but must not disturb the
    update magnitude the RMS-matching scale rule expects — this is the shared
    "equalize direction, preserve magnitude" epilogue."""
    norm = jnp.linalg.norm(reference, axis=(-2, -1), keepdims=True)
    norm_n = jnp.linalg.norm(normalized, axis=(-2, -1), keepdims=True)
    return normalized * norm / (norm_n + eps)


def apply_wd_and_lr(update: jax.Array, param: jax.Array, cfg) -> jax.Array:
    # fp32 update math when the master params are fp32; for bf16-master
    # configs (docs/DESIGN.md §8) stay in bf16 — the fp32 temp would be the
    # largest buffer in the program.
    cd = jnp.float32 if param.dtype == jnp.float32 else param.dtype
    u = update.astype(cd) + cfg.weight_decay * param.astype(cd)
    return (-cfg.learning_rate * u).astype(param.dtype)


# --------------------------------------------------------------------------
# AdamW (non-matrix leaves + the elementwise baseline variant)
# --------------------------------------------------------------------------

class AdamWState(NamedTuple):
    mu: Any
    nu: Any


def adamw_init(params) -> AdamWState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return AdamWState(mu=zeros,
                      nu=jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                      params))


def adamw_update(grads, state: AdamWState, params, step, cfg):
    b1, b2, eps = cfg.adam_b1, cfg.adam_b2, cfg.adam_eps
    mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32),
                      state.mu, grads)
    nu = jax.tree.map(
        lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(jnp.float32)),
        state.nu, grads)
    t = step.astype(jnp.float32) + 1.0
    bc1 = 1.0 - b1 ** t
    bc2 = 1.0 - b2 ** t

    def upd(m, v, p):
        u = (m / bc1) / (jnp.sqrt(v / bc2) + eps)
        u = u + cfg.adam_weight_decay * p.astype(jnp.float32)
        return (-cfg.adam_lr * u).astype(p.dtype)

    updates = jax.tree.map(upd, mu, nu, params)
    return updates, AdamWState(mu, nu)
