"""Fine-grained owner-slot layout (paper §3.2.1, Eq. 3, generalized).

The paper maps the logical index ``w`` of a matrix in the communication
schedule to an owner slot on a (nodes × gpus-per-node) mesh:

    gpu(w)  = w mod C
    node(w) = (w mod R) xor (floor(w / C) mod R)          (Eq. 3, 4×8 mesh)

The ``gpu`` term disperses consecutive matrices across the C inter-node
columns; the XOR term rotates the owner node across groups of C matrices, so
a lookahead window of publications never concentrates on a single column.

TPU adaptation: "columns" become positions along the fast mesh axis (the
'model' ICI ring), "nodes" the slower axis ('data', and the DCN 'pod' axis in
multi-pod meshes).  The layout orders owner slots in the stacked owner-sharded
buffers so that adjacent layers' collective traffic lands on different ICI
columns / pods (docs/DESIGN.md §2).

The XOR rule requires R to be a power of two (and balance additionally needs
R | C, as in the paper's 4×8); otherwise we fall back to an additive rotation
with identical dispersal and balance properties.
"""

from __future__ import annotations

import numpy as np


def _is_pow2(x: int) -> bool:
    return x > 0 and (x & (x - 1)) == 0


def owner_slot(w: int, rows: int, cols: int) -> int:
    """Owner slot (node*cols + gpu) for logical matrix index ``w`` (Eq. 3)."""
    gpu = w % cols
    if _is_pow2(rows) and cols % rows == 0:
        node = (w % rows) ^ ((w // cols) % rows)
    else:  # additive rotation: same dispersal, valid for any (rows, cols)
        node = (w % rows + (w // cols)) % rows
    return node * cols + gpu


def slot_sequence(count: int, rows: int, cols: int) -> np.ndarray:
    """Owner slots for matrices w = 0..count-1."""
    return np.asarray([owner_slot(w, rows, cols) for w in range(count)],
                      dtype=np.int64)


def xor_permutation(count: int, rows: int, cols: int) -> np.ndarray:
    """A permutation of 0..count-1 ordering matrices so that, scanned in
    order, their owner slots follow the XOR layout.

    Used to order members inside a stacked owner-sharded shape group: position
    p of the padded stack belongs to owner ``p // capacity``; this permutation
    spreads consecutive logical matrices (adjacent layers) over distinct
    columns exactly as Fig. 4 does.
    """
    d = rows * cols
    slots = slot_sequence(count, rows, cols)
    # stable order: sort by (slot, arrival) — matrices owned by slot s keep
    # their schedule order within the slot.
    order = np.lexsort((np.arange(count), slots))
    del d
    return order


def column_of_slot(slot: int, cols: int) -> int:
    return slot % cols


def node_of_slot(slot: int, cols: int) -> int:
    return slot // cols
