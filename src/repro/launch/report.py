"""Generate the §Dry-run and §Roofline tables of EXPERIMENTS.md from the
artifacts under experiments/dryrun/.

    PYTHONPATH=src python -m repro.launch.report > experiments/tables.md
"""

from __future__ import annotations

import glob
import json
import os

RESULT_DIR = os.path.abspath(os.path.join(
    os.path.dirname(os.path.abspath(__file__)), "../../..", "experiments",
    "dryrun"))

ARCH_ORDER = ["hymba-1.5b", "qwen2.5-14b", "nemotron-4-340b", "smollm-360m",
              "stablelm-1.6b", "deepseek-v3-671b", "kimi-k2-1t-a32b",
              "xlstm-350m", "seamless-m4t-large-v2", "llava-next-mistral-7b"]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(mesh):
    out = {}
    for fp in glob.glob(os.path.join(RESULT_DIR, mesh, "*.json")):
        with open(fp) as f:
            d = json.load(f)
        out[(d["arch"], d["shape"])] = d
    return out


def fmt_bytes(b):
    return f"{b/2**30:.2f}"


def dryrun_table(mesh):
    cells = load(mesh)
    rows = [f"#### Mesh `{mesh}` "
            f"({'2×16×16 = 512 chips' if mesh == 'multi' else '16×16 = 256 chips'})",
            "",
            "| arch | shape | status | HBM GiB/chip (util) | per-dev GFLOPs | "
            "coll GiB/dev | compile s |",
            "|---|---|---|---|---|---|---|"]
    for a in ARCH_ORDER:
        for s in SHAPE_ORDER:
            d = cells.get((a, s))
            if d is None:
                continue
            if d.get("skipped"):
                rows.append(f"| {a} | {s} | SKIP (sub-quadratic rule) "
                            f"| — | — | — | — |")
                continue
            if not d.get("ok"):
                rows.append(f"| {a} | {s} | **FAIL** {d.get('error','')[:40]}"
                            f" | — | — | — | — |")
                continue
            r = d["roofline"]
            mem = d["memory_analysis"]["total_bytes"]
            util = d["hbm_utilization"]
            flag = "" if d["fits_hbm"] else " ⚠"
            rows.append(
                f"| {a} | {s} | ok | {fmt_bytes(mem)} ({util:.2f}×){flag} | "
                f"{r['flops']/1e9:.0f} | {r['coll_bytes']/2**30:.1f} | "
                f"{d['timings_s']['compile']:.0f} |")
    return "\n".join(rows)


def roofline_table(mesh="single"):
    cells = load(mesh)
    rows = ["| arch | shape | compute s | memory s | collective s | dominant"
            " | MODEL_FLOPS/dev | useful ratio | kernel-adj compute s |",
            "|---|---|---|---|---|---|---|---|---|"]
    for a in ARCH_ORDER:
        for s in SHAPE_ORDER:
            d = cells.get((a, s))
            if d is None or d.get("skipped") or not d.get("ok"):
                continue
            r = d["roofline"]
            kadj = r.get("kernel_adjusted_compute_s")
            rows.append(
                f"| {a} | {s} | {r['compute_s']:.4f} | {r['memory_s']:.4f} |"
                f" {r['collective_s']:.4f} | **{r['dominant']}** |"
                f" {r['model_flops']:.3e} | {r['useful_ratio']:.2f} |"
                f" {kadj:.4f} |" if kadj else
                f"| {a} | {s} | {r['compute_s']:.4f} | {r['memory_s']:.4f} |"
                f" {r['collective_s']:.4f} | **{r['dominant']}** |"
                f" {r['model_flops']:.3e} | {r['useful_ratio']:.2f} | — |")
    return "\n".join(rows)


def summary():
    out = {}
    for mesh in ("single", "multi"):
        cells = load(mesh)
        ok = sum(1 for d in cells.values() if d.get("ok"))
        skip = sum(1 for d in cells.values() if d.get("skipped"))
        fail = len(cells) - ok - skip
        out[mesh] = (ok, skip, fail, len(cells))
    return out


def main():
    s = summary()
    print("## §Dry-run\n")
    for mesh, (ok, skip, fail, total) in s.items():
        print(f"- **{mesh}**: {ok} ok, {skip} skipped (assignment rule), "
              f"{fail} failed, {total} cells")
    print()
    for mesh in ("single", "multi"):
        print(dryrun_table(mesh))
        print()
    print("## §Roofline (single-pod baseline, per §Perf hillclimbs)\n")
    print(roofline_table("single"))


if __name__ == "__main__":
    main()
