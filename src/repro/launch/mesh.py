"""Production mesh definition (deliverable e).

A FUNCTION, not a module-level constant, so importing this module never
touches jax device state — the dry-run sets XLA_FLAGS before first init.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 = 256 chips per pod; 2×16×16 = 512 chips across two pods."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


# TPU v5e hardware constants (roofline denominators)
PEAK_FLOPS_BF16 = 197e12       # FLOP/s per chip
HBM_BW = 819e9                 # bytes/s per chip
ICI_BW = 50e9                  # bytes/s per link
HBM_BYTES = 16 * 2**30         # per-chip HBM capacity
