"""Roofline analysis from compiled dry-run artifacts (deliverable g).

Three terms per (arch × shape × mesh), in seconds:

    compute    = HLO_FLOPs            / (peak FLOP/s per chip)
    memory     = HLO_bytes_accessed   / (HBM bytes/s per chip)
    collective = collective_bytes     / (ICI bytes/s per chip link)

``compiled.cost_analysis()`` supplies per-device FLOPs and bytes; collective
bytes are NOT in cost_analysis, so we parse the optimized HLO text and sum
the operand sizes of every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute op.

Kernel adjustment: the dry-run lowers the pure-jnp Gram-NS path (Pallas
grids cannot be lowered on the CPU backend — docs/DESIGN.md §2), so the HLO
compute term counts full GEMMs for the symmetric products.  On TPU the
symmetric kernels execute ~half of that; we report both the raw-HLO term and
the kernel-adjusted term using the analytic model in core/gram_ns.py.
"""

from __future__ import annotations

import re
from dataclasses import asdict, dataclass, field
from typing import Dict, Optional

from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# e.g.  f32[512,5120,5120]{2,1,0}  bf16[2,4096]{1,0}
_SHAPE_RE = re.compile(r"(pred|[a-z]+[0-9]+)\[([0-9,]*)\]")

_DTYPE_BYTES = {"pred": 1, "s4": 1, "u4": 1,
                "s8": 1, "u8": 1, "f8": 1,
                "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
                "s32": 4, "u32": 4, "f32": 4,
                "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16}


def _bytes_of_shape(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    for k, v in _DTYPE_BYTES.items():
        if dtype.startswith(k):
            return n * v
    return n * 4


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Per-kind collective operand bytes — trip-count-aware (hlo_walker)."""
    from repro.launch import hlo_walker
    costs = hlo_walker.analyze_text(hlo_text)
    out: Dict[str, int] = {k: int(v) for k, v in costs.coll.items()}
    out["total"] = int(costs.coll_total)
    return out


@dataclass
class Roofline:
    flops: float                   # per-device HLO flops
    hbm_bytes: float               # per-device bytes accessed
    coll_bytes: float              # per-device collective operand bytes
    compute_s: float = 0.0
    memory_s: float = 0.0
    collective_s: float = 0.0
    dominant: str = ""
    model_flops: float = 0.0       # 6·N·D (dense) or 6·N_active·D
    useful_ratio: float = 0.0      # MODEL_FLOPS / HLO_FLOPs
    kernel_adjusted_compute_s: Optional[float] = None
    detail: dict = field(default_factory=dict)

    def finalize(self):
        self.compute_s = self.flops / PEAK_FLOPS_BF16
        self.memory_s = self.hbm_bytes / HBM_BW
        self.collective_s = self.coll_bytes / ICI_BW
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        self.dominant = max(terms, key=terms.get)
        if self.flops:
            self.useful_ratio = self.model_flops / self.flops
        return self

    def to_dict(self):
        return asdict(self)


def analyze(compiled, hlo_text: str, *, num_devices: int,
            model_flops: float = 0.0,
            ns_flops_raw: float = 0.0,
            ns_flops_kernel: float = 0.0) -> Roofline:
    """Build the three-term roofline from a compiled step.

    cost_analysis flops/bytes are per-device under SPMD.  Collective bytes
    from the HLO are per-device operand sizes already.  ``ns_flops_raw`` /
    ``ns_flops_kernel``: per-device NS GEMM flops as lowered (full) vs as the
    Pallas symmetric kernel executes them — compute term is reported both
    ways.
    """
    cost = compiled.cost_analysis()
    if isinstance(cost, list):          # older jax returns [dict]
        cost = cost[0]
    raw_flops = float(cost.get("flops", 0.0))
    raw_bytes = float(cost.get("bytes accessed", 0.0))
    # Trip-count-corrected walk: XLA's cost_analysis counts while bodies once
    # (scan-over-layers would under-report by ~L) — see hlo_walker.py.
    from repro.launch import hlo_walker
    walked = hlo_walker.analyze_text(hlo_text)
    flops = max(raw_flops, walked.flops)
    nbytes = max(raw_bytes, walked.bytes)
    coll = {k: v for k, v in walked.coll.items()}
    coll["total"] = walked.coll_total
    r = Roofline(flops=flops, hbm_bytes=nbytes,
                 coll_bytes=float(coll["total"]),
                 model_flops=model_flops,
                 detail={"collectives": coll, "num_devices": num_devices,
                         "raw_cost_analysis": {"flops": raw_flops,
                                               "bytes": raw_bytes}})
    r.finalize()
    if ns_flops_raw and ns_flops_kernel and flops > ns_flops_raw:
        adj = flops - (ns_flops_raw - ns_flops_kernel)
        r.kernel_adjusted_compute_s = adj / PEAK_FLOPS_BF16
    return r


def memory_analysis_dict(compiled) -> dict:
    ma = compiled.memory_analysis()
    out = {}
    for k in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "generated_code_size_in_bytes",
              "alias_size_in_bytes"):
        out[k] = int(getattr(ma, k, 0) or 0)
    out["total_bytes"] = (out["argument_size_in_bytes"]
                          + out["output_size_in_bytes"]
                          + out["temp_size_in_bytes"]
                          - out.get("alias_size_in_bytes", 0))
    return out
