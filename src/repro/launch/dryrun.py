import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run (deliverable e): lower + compile every
(architecture × input shape) cell on the production meshes and record
memory_analysis / cost_analysis / collective schedule for §Dry-run and
§Roofline.

The two lines above MUST precede every other import (jax locks the device
count on first init).  Do not set this flag anywhere global — smoke tests
and benches see 1 device.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun                  # all cells
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2.5-14b \
        --shape train_4k --mesh single --opt owner
    PYTHONPATH=src python -m repro.launch.dryrun --list

Results land in experiments/dryrun/<mesh>/<arch>__<shape>__<opt>.json,
one file per cell, written incrementally (reruns skip finished cells unless
--force).
"""

import argparse
import dataclasses
import json
import time
import traceback
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import configs
from repro.configs.shapes import SHAPES, cell_supported, input_specs
from repro.core import api
from repro.core.gram_ns import GramNSConfig, gram_ns_flops
from repro.core.muon import MuonConfig, MuonState, muon_init
from repro.launch import roofline
from repro.launch.mesh import HBM_BYTES, make_production_mesh
from repro.models import model_fns, sharding as shard_rules
from repro.train.step import make_loss_fn
from repro.train.train_state import TrainState

RESULT_DIR = os.path.abspath(os.path.join(
    os.path.dirname(os.path.abspath(__file__)), "../../..", "experiments",
    "dryrun"))

# Memory policy (docs/DESIGN.md §8): ≥340B configs use ZeRO-3 param sharding and
# bf16 optimizer math end-to-end.
BIG_ARCHS = {"nemotron-4-340b", "deepseek-v3-671b", "kimi-k2-1t-a32b"}
MID_ARCHS = {"qwen2.5-14b", "llava-next-mistral-7b"}


def opt_config(arch_id: str, mode: str) -> MuonConfig:
    if arch_id in BIG_ARCHS:
        return MuonConfig(mode=mode, momentum_dtype="bfloat16",
                          pack_dtype="bfloat16",
                          ns=GramNSConfig(compute_dtype="bfloat16",
                                          owner_chunk=8))
    return MuonConfig(mode=mode)


def accum_steps(arch_id: str) -> int:
    # global microbatch stays divisible by DP on both meshes (>= 32)
    if arch_id in BIG_ARCHS or arch_id in MID_ARCHS:
        return 8
    return 4


def _sds(tree_shapes, shardings):
    """ShapeDtypeStructs carrying shardings — lowerable, no allocation."""
    return jax.tree.map(
        lambda s, sh: None if s is None
        else jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        tree_shapes, shardings,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct) or x is None)


def build_train_cell(cfg, arch_id, shape_name, mesh, mode):
    """Returns (fn, example_args) for one training cell."""
    m = model_fns(cfg)
    zero3 = arch_id in BIG_ARCHS
    param_shapes = jax.eval_shape(partial(m.init, cfg), jax.random.PRNGKey(0))
    plan = api.dedicate_params(param_shapes, mesh=mesh, strategy="greedy")
    opt = api.Muon(plan, mesh=mesh, config=opt_config(arch_id, mode))

    pspecs = shard_rules.param_specs(cfg, param_shapes, mesh, zero3=zero3)
    # per-leaf training specs let pack/unpack stage the owner reshard at
    # identical stacked shapes (no whole-tensor rematerialization)
    from repro.core.dedication import _key_str
    spec_by_path = {}
    for kp, spec in jax.tree_util.tree_leaves_with_path(
            pspecs, is_leaf=lambda x: isinstance(x, P)):
        spec_by_path["/".join(_key_str(k) for k in kp)] = spec
    plan.train_specs = spec_by_path
    pshard = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs,
                          is_leaf=lambda x: isinstance(x, P))
    params_in = _sds(param_shapes, pshard)

    opt_shapes = jax.eval_shape(partial(muon_init, plan, param_shapes,
                                        opt.config))
    from repro.train.step import _opt_state_shardings
    oshard = _opt_state_shardings(opt, opt_shapes, pspecs, mesh)
    opt_in = _sds(opt_shapes, oshard)

    scalar = NamedSharding(mesh, P())
    state_in = TrainState(
        step=jax.ShapeDtypeStruct((), jnp.int32, sharding=scalar),
        params=params_in, opt_state=opt_in,
        loss_ema=jax.ShapeDtypeStruct((), jnp.float32, sharding=scalar))

    specs = input_specs(cfg, shape_name)
    ishard = shard_rules.input_shardings(cfg, specs, mesh)
    # shard the long frame/patch prefix over 'model' too (activations policy)
    for k in ("frames", "patches"):
        if k in specs and specs[k].shape[1] % mesh.shape["model"] == 0:
            bs = shard_rules.batch_spec(mesh, specs[k].shape[0])
            ishard[k] = NamedSharding(mesh, P(*(tuple(bs) + ("model", None))))
    batch_in = {k: jax.ShapeDtypeStruct(v.shape, v.dtype,
                                        sharding=ishard[k])
                for k, v in specs.items()}

    from repro.train.step import make_train_step
    step = make_train_step(
        cfg, opt, mesh, accum_steps=accum_steps(arch_id), donate=True,
        grad_specs=pspecs,
        accum_dtype=jnp.bfloat16 if arch_id in BIG_ARCHS else jnp.float32)
    return step, (state_in, batch_in), plan


def build_serve_cell(cfg, arch_id, shape_name, mesh):
    """prefill or decode cell; params in serving dtype (bf16)."""
    m = model_fns(cfg)
    sp = SHAPES[shape_name]
    param_shapes = jax.eval_shape(partial(m.init, cfg), jax.random.PRNGKey(0))
    pspecs = shard_rules.param_specs(cfg, param_shapes, mesh,
                                     zero3=arch_id in BIG_ARCHS)
    pshard = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs,
                          is_leaf=lambda x: isinstance(x, P))
    params_in = _sds(param_shapes, pshard)

    specs = input_specs(cfg, shape_name)
    ishard = shard_rules.input_shardings(cfg, specs, mesh)
    for k in ("frames", "patches"):
        if k in specs and specs[k].shape[1] % mesh.shape["model"] == 0:
            bs = shard_rules.batch_spec(mesh, specs[k].shape[0])
            ishard[k] = NamedSharding(mesh, P(*(tuple(bs) + ("model", None))))
    inputs_in = {k: jax.ShapeDtypeStruct(v.shape, v.dtype, sharding=ishard[k])
                 for k, v in specs.items()}

    from repro.train.serve import decode_fn, make_cache_shapes, prefill_fn
    if sp.kind == "prefill":
        def fn(params, inputs):
            return prefill_fn(cfg, params, inputs["tokens"],
                              sp.seq_len + (cfg.frontend_len
                                            if cfg.frontend == "patch" else 0),
                              **{k: v for k, v in inputs.items()
                                 if k != "tokens"})
        return jax.jit(fn), (params_in, inputs_in)

    # decode: one token against a seq_len-deep cache
    cache_shapes = make_cache_shapes(cfg, sp.global_batch, sp.seq_len)
    cspecs = shard_rules.cache_specs(cfg, cache_shapes, mesh)
    cshard = jax.tree.map(lambda s: NamedSharding(mesh, s), cspecs,
                          is_leaf=lambda x: isinstance(x, P))
    cache_in = _sds(cache_shapes, cshard)

    def fn(params, token, cache, pos):
        return decode_fn(cfg, params, token, cache, pos)
    return (jax.jit(fn, donate_argnums=(2,)),
            (params_in, inputs_in["token"], cache_in, inputs_in["pos"]))


def ns_flops_for_plan(plan, ns_steps: int, num_devices: int):
    raw = kern = 0.0
    for key, g in plan.groups.items():
        m, n = g.key
        f = gram_ns_flops(m, n, ns_steps, batch=g.packed_size)
        raw += f["gram_full_gemm"]
        kern += f["gram_symmetric_kernel"]
    return raw / num_devices, kern / num_devices


def run_cell(arch_id: str, shape_name: str, mesh_kind: str, mode: str,
             outdir: str, force: bool = False) -> dict:
    tag = f"{arch_id}__{shape_name}__{mode}"
    mesh_dir = os.path.join(outdir, mesh_kind)
    os.makedirs(mesh_dir, exist_ok=True)
    out_path = os.path.join(mesh_dir, tag + ".json")
    if os.path.exists(out_path) and not force:
        with open(out_path) as f:
            return json.load(f)

    sp = SHAPES[shape_name]
    serve_dtypes = (dict(param_dtype="bfloat16", compute_dtype="bfloat16")
                    if sp.kind != "train" else {})
    cfg = configs.get(arch_id, **serve_dtypes)
    result = {"arch": arch_id, "shape": shape_name, "mesh": mesh_kind,
              "opt": mode, "kind": sp.kind}

    skip = cell_supported(cfg, shape_name)
    if skip:
        result["skipped"] = skip
        _write(out_path, result)
        return result

    try:
        mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
        ndev = int(np.prod(list(mesh.shape.values())))
        dp = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
        seq_ax = None
        if cfg.n_heads % mesh.shape["model"] != 0 and sp.kind != "decode":
            seq_ax = "model"   # sequence-sharded attention (heads indivisible)
        if sp.kind == "train":
            # FSDP/ZeRO-3 discipline: pin activation batch sharding at block
            # boundaries (lowered under the mesh context).  MoE blocks skip
            # the pin — it fights the expert-dispatch resharding (§Perf).
            pin = dp if cfg.moe is None else None
            cfg = dataclasses.replace(cfg, act_batch_axes=pin,
                                      act_seq_axis=seq_ax)
        elif seq_ax is not None:
            cfg = dataclasses.replace(cfg, act_batch_axes=dp,
                                      act_seq_axis=seq_ax)
        t0 = time.time()
        plan = None
        if sp.kind == "train":
            fn, args, plan = build_train_cell(cfg, arch_id, shape_name, mesh,
                                              mode)
        else:
            fn, args = build_serve_cell(cfg, arch_id, shape_name, mesh)
        t_build = time.time() - t0

        t0 = time.time()
        with jax.sharding.set_mesh(mesh):
            lowered = fn.lower(*args)
        t_lower = time.time() - t0
        t0 = time.time()
        with jax.sharding.set_mesh(mesh):
            compiled = lowered.compile()
        t_compile = time.time() - t0

        mem = roofline.memory_analysis_dict(compiled)
        hlo = compiled.as_text()

        n_params = cfg.param_count()
        n_active = cfg.active_param_count()
        tokens = sp.global_batch * (sp.seq_len if sp.kind != "decode" else 1)
        if sp.kind == "train":
            model_flops = 6.0 * n_active * tokens / ndev
        else:
            model_flops = 2.0 * n_active * tokens / ndev
        nsr = nsk = 0.0
        if plan is not None and mode == "owner":
            nsr, nsk = ns_flops_for_plan(plan, 5, ndev)
        r = roofline.analyze(compiled, hlo, num_devices=ndev,
                             model_flops=model_flops,
                             ns_flops_raw=nsr, ns_flops_kernel=nsk)

        result.update({
            "ok": True,
            "num_devices": ndev,
            "timings_s": {"build": t_build, "lower": t_lower,
                          "compile": t_compile},
            "memory_analysis": mem,
            "hbm_utilization": mem["total_bytes"] / HBM_BYTES,
            "fits_hbm": mem["total_bytes"] <= HBM_BYTES,
            "roofline": r.to_dict(),
            "params": n_params, "active_params": n_active,
            "tokens_per_step": tokens,
        })
        if plan is not None:
            result["plan_stats"] = plan.stats
    except Exception as e:  # noqa: BLE001 — record the failure, keep going
        result.update({"ok": False, "error": repr(e),
                       "traceback": traceback.format_exc()})
    _write(out_path, result)
    return result


def _write(path, obj):
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(obj, f, indent=1, default=str)
    os.replace(tmp, path)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default=None, choices=[None, "single", "multi"])
    ap.add_argument("--opt", default="owner",
                    choices=["owner", "gather", "adamw"])
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--list", action="store_true")
    ap.add_argument("--outdir", default=RESULT_DIR)
    args = ap.parse_args()

    archs = [args.arch] if args.arch else list(configs.ARCH_IDS)
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = [args.mesh] if args.mesh else ["single", "multi"]

    cells = [(a, s, mk) for mk in meshes for a in archs for s in shapes]
    if args.list:
        for c in cells:
            print(*c)
        return

    n_ok = n_skip = n_fail = 0
    for a, s, mk in cells:
        t0 = time.time()
        r = run_cell(a, s, mk, args.opt, args.outdir, force=args.force)
        dt = time.time() - t0
        if r.get("skipped"):
            n_skip += 1
            status = "SKIP " + r["skipped"][:40]
        elif r.get("ok"):
            n_ok += 1
            ra = r["roofline"]
            status = (f"ok mem={r['hbm_utilization']:.2f}HBM "
                      f"dom={ra['dominant']} "
                      f"c={ra['compute_s']:.4f}s m={ra['memory_s']:.4f}s "
                      f"x={ra['collective_s']:.4f}s")
        else:
            n_fail += 1
            status = "FAIL " + r.get("error", "?")[:80]
        print(f"[{mk:6s}] {a:24s} {s:12s} {dt:7.1f}s  {status}",
              flush=True)
    print(f"\ndone: {n_ok} ok, {n_skip} skipped, {n_fail} failed")


if __name__ == "__main__":
    main()
