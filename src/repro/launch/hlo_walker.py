"""Trip-count-aware HLO cost walker.

XLA's ``compiled.cost_analysis()`` counts each ``while`` body **once**, so
scan-over-layers programs (every backbone here) under-report FLOPs, bytes and
collective volume by ~the layer count.  This walker parses the optimized HLO
text, builds the computation graph, infers loop trip counts from the loop
condition's comparison constant, and accumulates

    flops       — 2 · |out| · contracted_dim for every dot
    bytes       — operand + output sizes at instruction granularity
                  (fusion internals excluded: a fusion instruction reads its
                  operands and writes its output, like XLA's model)
    coll_bytes  — operand bytes of all-gather / all-reduce / reduce-scatter /
                  all-to-all / collective-permute, per kind

multiplying every ``while`` body by its trip count (nested loops compose).
Validated against analytic 6·N·D in tests/test_roofline.py.
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {"pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
                "f8e4m3": 1, "f8e5m2": 1,
                "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
                "s32": 4, "u32": 4, "f32": 4,
                "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")
_INSTR_RE = re.compile(r"^\s*(?:ROOT\s+)?(%[\w\.\-]+)\s*=\s*(.*)$")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?(%[\w\.\-]+)\s*\(.*\)\s*->.*{")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def _shape_elems(dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n


@dataclass
class Instr:
    name: str
    rhs: str                      # everything right of '='
    out_shapes: List[Tuple[str, str]]   # [(dtype, dims)] (tuples flattened)
    op: str


@dataclass
class Computation:
    name: str
    instrs: List[Instr] = field(default_factory=list)
    by_name: Dict[str, Instr] = field(default_factory=dict)


def parse_hlo(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for raw in text.splitlines():
        line = raw.rstrip()
        if cur is None:
            m = _COMP_HDR_RE.match(line.strip())
            if m:
                cur = Computation(m.group(1))
            continue
        if line.strip() == "}":
            comps[cur.name] = cur
            cur = None
            continue
        m = _INSTR_RE.match(line)
        if not m:
            continue
        name, rhs = m.group(1), m.group(2)
        # output shapes: the leading type expression before the op name
        opm = re.search(r"\)?\s*([a-z][a-z0-9\-]*)\(", rhs)
        op = opm.group(1) if opm else ""
        head = rhs[:opm.start(1)] if opm else rhs
        out_shapes = _SHAPE_RE.findall(head)
        instr = Instr(name, rhs, out_shapes, op)
        cur.instrs.append(instr)
        cur.by_name[name] = instr
    return comps


def _called(rhs: str, attr: str) -> Optional[str]:
    m = re.search(attr + r"=(%[\w\.\-]+)", rhs)
    return m.group(1) if m else None


def _calls_list(rhs: str) -> List[str]:
    m = re.search(r"calls=(%[\w\.\-]+)", rhs)
    return [m.group(1)] if m else []


def trip_count(cond: Computation) -> int:
    """Heuristic: scan conditions compare the induction var against a
    constant; take the largest s32 constant in the condition computation."""
    best = 1
    for ins in cond.instrs:
        for m in re.finditer(r"constant\((\d+)\)", ins.rhs):
            best = max(best, int(m.group(1)))
    return best


@dataclass
class Costs:
    flops: float = 0.0
    bytes: float = 0.0
    coll: Dict[str, float] = field(default_factory=lambda: {
        k: 0.0 for k in _COLLECTIVES})

    def scaled(self, k: float) -> "Costs":
        return Costs(self.flops * k, self.bytes * k,
                     {kk: v * k for kk, v in self.coll.items()})

    def add(self, o: "Costs"):
        self.flops += o.flops
        self.bytes += o.bytes
        for k, v in o.coll.items():
            self.coll[k] += v

    @property
    def coll_total(self) -> float:
        return sum(self.coll.values())


def _operand_bytes(comp: Computation, rhs: str) -> int:
    """Bytes of operands named inside the call parens (looked up by name),
    plus any inline-shaped operands."""
    paren = rhs[rhs.index("("):] if "(" in rhs else rhs
    # operands carry either inline shapes (full HLO form) or bare %refs —
    # prefer inline to avoid double counting
    inline = _SHAPE_RE.findall(paren)
    if inline:
        return sum(_shape_bytes(d, dims) for d, dims in inline)
    total = 0
    for ref in re.findall(r"%[\w\.\-]+", paren):
        ins = comp.by_name.get(ref)
        if ins is not None:
            for dtype, dims in ins.out_shapes:
                total += _shape_bytes(dtype, dims)
    return total


def _dot_flops(comp: Computation, ins: Instr) -> float:
    out_elems = sum(_shape_elems(dims) for _, dims in ins.out_shapes)
    m = re.search(r"lhs_contracting_dims={([0-9,]*)}", ins.rhs)
    cdims = [int(x) for x in m.group(1).split(",")] if m and m.group(1) else []
    # lhs operand: first %ref or inline shape inside parens
    paren = ins.rhs[ins.rhs.index("("):]
    lhs_shape = None
    inline = _SHAPE_RE.findall(paren)
    refs = re.findall(r"%[\w\.\-]+", paren)
    if inline:
        lhs_shape = inline[0][1]
    elif refs and refs[0] in comp.by_name:
        shp = comp.by_name[refs[0]].out_shapes
        if shp:
            lhs_shape = shp[0][1]
    contracted = 1
    if lhs_shape:
        dims = [int(x) for x in lhs_shape.split(",")] if lhs_shape else []
        for c in cdims:
            if c < len(dims):
                contracted *= dims[c]
    return 2.0 * out_elems * contracted


def computation_costs(comps: Dict[str, Computation], name: str,
                      memo: Dict[str, Costs]) -> Costs:
    if name in memo:
        return memo[name]
    memo[name] = Costs()            # cycle guard
    comp = comps.get(name)
    if comp is None:
        return memo[name]
    total = Costs()
    for ins in comp.instrs:
        op = ins.op
        if op == "dot" or op == "convolution":
            total.flops += _dot_flops(comp, ins)
            total.bytes += _operand_bytes(comp, ins.rhs) + sum(
                _shape_bytes(d, s) for d, s in ins.out_shapes)
        elif op == "while":
            body = _called(ins.rhs, "body")
            cond = _called(ins.rhs, "condition")
            trips = trip_count(comps[cond]) if cond in comps else 1
            inner = computation_costs(comps, body, memo)
            total.add(inner.scaled(max(trips, 1)))
        elif op == "fusion":
            # fused region: internal temporaries live in registers — count
            # only its FLOPs (rare fused dots) plus the fusion's own
            # operand/output HBM traffic.
            for callee in _calls_list(ins.rhs):
                inner = computation_costs(comps, callee, memo)
                total.flops += inner.flops
                for k, v in inner.coll.items():
                    total.coll[k] += v
            total.bytes += _operand_bytes(comp, ins.rhs) + sum(
                _shape_bytes(d, s) for d, s in ins.out_shapes)
        elif op in ("call", "map", "conditional", "custom-call", "sort",
                    "reduce", "reduce-window", "scatter"):
            for callee in _calls_list(ins.rhs):
                total.add(computation_costs(comps, callee, memo))
            for br in re.findall(
                    r"(?:true_computation|false_computation|"
                    r"branch_computations)={?(%[\w\.\-]+)", ins.rhs):
                total.add(computation_costs(comps, br, memo))
            total.bytes += _operand_bytes(comp, ins.rhs) + sum(
                _shape_bytes(d, s) for d, s in ins.out_shapes)
        else:
            kind = next((k for k in _COLLECTIVES if op.startswith(k)), None)
            if kind is not None:
                nbytes = _operand_bytes(comp, ins.rhs)
                total.coll[kind] += nbytes
                total.bytes += nbytes + sum(
                    _shape_bytes(d, s) for d, s in ins.out_shapes)
            elif op in ("parameter", "constant", "tuple",
                        "get-tuple-element", "bitcast"):
                pass                 # no HBM traffic modelled
            else:
                total.bytes += _operand_bytes(comp, ins.rhs) + sum(
                    _shape_bytes(d, s) for d, s in ins.out_shapes)
    memo[name] = total
    return total


def analyze_text(text: str) -> Costs:
    comps = parse_hlo(text)
    entry = None
    for line in text.splitlines():
        if line.startswith("ENTRY"):
            m = re.match(r"ENTRY\s+(%[\w\.\-]+)", line)
            if m:
                entry = m.group(1)
            break
    if entry is None:       # fall back: main-like computation
        entry = next((n for n in comps if "main" in n), None)
    memo: Dict[str, Costs] = {}
    return computation_costs(comps, entry, memo)
