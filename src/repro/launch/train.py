"""Production training driver on the resilient supervisor loop.

    PYTHONPATH=src python -m repro.launch.train --arch smollm-360m \
        --reduced --steps 50 --opt owner --ckpt-dir /tmp/ckpt

On real hardware this launches against the production mesh; on this CPU
container use --reduced for the smoke-scale config.  The run is supervised
by ``runtime/resilient.py``: streaming deterministic pipeline with a
checkpointable cursor, rotating async checkpoints (train tree + data state),
straggler monitoring with online re-dedication, and elastic recovery from
owner loss / preemption.  ``--faults`` injects a scripted adversity drill
(``runtime/faults.py`` DSL) — the same harness the soak test and
``benchmarks/soak_bench.py`` drive.
"""

from __future__ import annotations

import argparse

import jax
import numpy as np

from repro import configs
from repro.core.gram_ns import GramNSConfig
from repro.core.muon import MuonConfig
from repro.data.pipeline import DataConfig
from repro.runtime.elastic import remesh
from repro.runtime.faults import FaultPlan
from repro.runtime.resilient import ResilientConfig, ResilientLoop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list(configs.ARCH_IDS))
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--opt", default="owner",
                    choices=["owner", "gather", "adamw"])
    ap.add_argument("--variant", default="muon",
                    help="optimizer variant (registry in core/api.py)")
    ap.add_argument("--strategy", default="load_balance",
                    choices=["load_balance", "greedy", "lpt", "round_robin",
                             "rank0", "xor"])
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=0.02)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--pipeline", default="fused",
                    choices=["fused", "bucketed"])
    ap.add_argument("--owners", type=int, default=None,
                    help="owner slots when running without a mesh "
                         "(default: device count)")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--mesh", action="store_true",
                    help="build a mesh over all visible devices")
    ap.add_argument("--faults", default=None, metavar="SPEC",
                    help="fault-injection drill, e.g. "
                         "'slow@8:r3x4.0; kill@30:r1; readd@40; preempt@52'")
    ap.add_argument("--no-rebalance", action="store_true",
                    help="disable online straggler re-dedication")
    ap.add_argument("--rebalance-window", type=int, default=20)
    ap.add_argument("--rebalance-threshold", type=float, default=1.3)
    args = ap.parse_args()

    cfg = configs.get(args.arch, reduced=args.reduced)
    if cfg.frontend is not None or cfg.encdec:
        raise SystemExit("use examples/serve_decode.py for frontend archs, "
                         "or extend the batch builder with frames/patches")

    mesh = remesh() if args.mesh and len(jax.devices()) > 1 else None
    mcfg = MuonConfig(mode=args.opt, variant=args.variant,
                      learning_rate=args.lr, pipeline=args.pipeline,
                      ns=GramNSConfig())
    rcfg = ResilientConfig(
        steps=args.steps,
        ckpt_every=args.ckpt_every if args.ckpt_dir else 0,
        strategy=args.strategy, accum_steps=args.accum,
        rebalance=not args.no_rebalance, window=args.rebalance_window,
        threshold=args.rebalance_threshold)
    dcfg = DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                      global_batch=args.batch)
    faults = FaultPlan.parse(args.faults) if args.faults else None

    loop = ResilientLoop(
        cfg, dcfg, muon=mcfg, run=rcfg,
        num_owners=args.owners or len(jax.devices()), mesh=mesh,
        ckpt_dir=args.ckpt_dir, faults=faults, resume=args.resume,
        log=lambda *a: print(*a, flush=True))
    print(f"[plan] {loop.plan.stats}")
    if args.resume and int(np.asarray(loop.state.step)):
        print(f"[resume] step {int(np.asarray(loop.state.step))}")

    report = loop.run()
    if report.rebalances:
        print(f"[rebalances] {len(report.rebalances)} "
              f"(last speeds {np.round(report.rebalances[-1]['speed'], 3)})")
    if report.recoveries:
        print(f"[recoveries] "
              f"{[(r['kind'], r['step']) for r in report.recoveries]}")
    print(f"[done] steps={report.steps} owners={report.final_owner_count} "
          f"loss_ema={float(loop.state.loss_ema):.4f} "
          f"avg_step={np.mean(report.step_times)*1e3:.0f} ms")


if __name__ == "__main__":
    main()
