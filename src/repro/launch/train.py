"""Production training driver (deliverable a/b): --arch × --shape × --opt.

    PYTHONPATH=src python -m repro.launch.train --arch smollm-360m \
        --reduced --steps 50 --opt owner

On real hardware this launches against the production mesh; on this CPU
container use --reduced for the smoke-scale config.  Wires together every
substrate: config registry, dedication plan + MILP/greedy balancing,
owner-centric DMuon, deterministic pipeline, checkpoint manager with
rotation + async commit, straggler monitor.
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro import configs
from repro.checkpoint.manager import CheckpointManager
from repro.core import api
from repro.core.gram_ns import GramNSConfig
from repro.core.muon import MuonConfig
from repro.data.pipeline import DataConfig, Pipeline
from repro.models import model_fns
from repro.runtime.elastic import StepTimer, StragglerMonitor, remesh
from repro.train.step import init_state, make_train_step
from repro.train.train_state import TrainState


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list(configs.ARCH_IDS))
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--opt", default="owner",
                    choices=["owner", "gather", "adamw"])
    ap.add_argument("--strategy", default="load_balance",
                    choices=["load_balance", "greedy", "lpt", "round_robin",
                             "rank0", "xor"])
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=0.02)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--mesh", action="store_true",
                    help="build a mesh over all visible devices")
    args = ap.parse_args()

    cfg = configs.get(args.arch, reduced=args.reduced)
    if cfg.frontend is not None or cfg.encdec:
        raise SystemExit("use examples/serve_decode.py for frontend archs, "
                         "or extend the batch builder with frames/patches")

    mesh = remesh() if args.mesh and len(jax.devices()) > 1 else None
    shapes = jax.eval_shape(lambda k: model_fns(cfg).init(cfg, k),
                            jax.random.PRNGKey(0))
    plan = api.dedicate_params(shapes, mesh=mesh, strategy=args.strategy)
    opt = api.Muon(plan, mesh=mesh,
                   config=MuonConfig(mode=args.opt, learning_rate=args.lr,
                                     ns=GramNSConfig()))
    print(f"[plan] {plan.stats}")

    state = init_state(cfg, opt, jax.random.PRNGKey(0), mesh=mesh)
    mgr = CheckpointManager(args.ckpt_dir, keep=3) if args.ckpt_dir else None
    start = 0
    if args.resume and mgr is not None and mgr.latest_step() is not None:
        state = TrainState(**mgr.restore(like=state._asdict()))
        start = int(state.step)
        print(f"[resume] step {start}")

    step = make_train_step(cfg, opt, mesh, accum_steps=args.accum,
                           donate=False)
    dcfg = DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                      global_batch=args.batch)
    pipe = Pipeline(dcfg, mesh=mesh, start_step=start)
    monitor = StragglerMonitor(num_owners=plan.num_owners)
    timer = StepTimer()

    try:
        for i in range(start, args.steps):
            with timer:
                state = step(state, next(pipe))
                jax.block_until_ready(state.loss_ema)
            if (i + 1) % 10 == 0:
                print(f"step {i+1:5d} loss_ema {float(state.loss_ema):.4f} "
                      f"{np.mean(timer.history[-10:])*1e3:.0f} ms/step",
                      flush=True)
            if mgr is not None and (i + 1) % args.ckpt_every == 0:
                mgr.save(i + 1, state._asdict())
    finally:
        pipe.close()
        if mgr is not None:
            mgr.wait()
    print(f"[done] steps={int(state.step)} loss_ema="
          f"{float(state.loss_ema):.4f}")


if __name__ == "__main__":
    main()
