"""hymba-1.5b [hybrid] — parallel attention ‖ mamba heads (arXiv:2411.13676).

32L d_model=1600 25H (GQA kv=5) d_ff=5504 vocab=32001, ssm_state=16.
Sliding-window attention everywhere except 3 global-attention layers
(first/middle/last, per the Hymba paper) → sub-quadratic ⇒ runs long_500k.
"""

from repro.models.ssm import SSMConfig
from repro.models.transformer import ArchConfig

ARCH_ID = "hymba-1.5b"


def config(**overrides) -> ArchConfig:
    base = dict(
        name=ARCH_ID, family="hybrid",
        n_layers=32, d_model=1600, n_heads=25, n_kv_heads=5,
        d_ff=5504, vocab=32001, head_dim=64,
        sliding_window=1024, global_layers=(0, 15, 31),
        ssm=SSMConfig(d_model=1600, d_state=16, expand=2),
    )
    base.update(overrides)
    return ArchConfig(**base)


def reduced(**overrides) -> ArchConfig:
    base = dict(
        name=ARCH_ID + "-reduced", family="hybrid",
        n_layers=4, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=128, vocab=257, head_dim=16,
        sliding_window=8, global_layers=(0, 3),
        ssm=SSMConfig(d_model=64, d_state=4, expand=2),
        remat=False,
    )
    base.update(overrides)
    return ArchConfig(**base)
