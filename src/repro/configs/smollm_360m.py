"""smollm-360m [dense] — llama-arch small (hf:HuggingFaceTB/SmolLM).

32L d_model=960 15H (GQA kv=5) d_ff=2560 vocab=49152.  Tied embeddings.
Full attention ⇒ long_500k skipped.  Also the end-to-end training example
(examples/train_smollm.py) — ~360M params is the "~100M-scale" driver here.
"""

from repro.models.transformer import ArchConfig

ARCH_ID = "smollm-360m"


def config(**overrides) -> ArchConfig:
    base = dict(
        name=ARCH_ID, family="dense",
        n_layers=32, d_model=960, n_heads=15, n_kv_heads=5,
        d_ff=2560, vocab=49152, head_dim=64,
        tie_embeddings=True,
    )
    base.update(overrides)
    return ArchConfig(**base)


def reduced(**overrides) -> ArchConfig:
    base = dict(
        name=ARCH_ID + "-reduced", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=160, vocab=257, head_dim=16, tie_embeddings=True,
        remat=False,
    )
    base.update(overrides)
    return ArchConfig(**base)
