"""nemotron-4-340b [dense] — GQA + squared-ReLU MLP (arXiv:2402.16819).

96L d_model=18432 96H (GQA kv=8) d_ff=73728 vocab=256000.
Full attention ⇒ long_500k skipped.  ZeRO-3 parameter sharding + bf16 states
required at 256–512 chips (docs/DESIGN.md §8).
"""

from repro.models.transformer import ArchConfig

ARCH_ID = "nemotron-4-340b"


def config(**overrides) -> ArchConfig:
    base = dict(
        name=ARCH_ID, family="dense",
        n_layers=96, d_model=18432, n_heads=96, n_kv_heads=8,
        d_ff=73728, vocab=256000, head_dim=192,
        act="squared_relu",
        param_dtype="bfloat16", compute_dtype="bfloat16",
    )
    base.update(overrides)
    return ArchConfig(**base)


def reduced(**overrides) -> ArchConfig:
    base = dict(
        name=ARCH_ID + "-reduced", family="dense",
        n_layers=2, d_model=96, n_heads=4, n_kv_heads=2,
        d_ff=384, vocab=257, head_dim=24, act="squared_relu",
        remat=False,
    )
    base.update(overrides)
    return ArchConfig(**base)
