"""Assigned input shapes and per-(arch × shape) input specs.

LM transformer shapes are seq_len × global_batch:
    train_4k     seq 4,096   gb 256   (training)        -> train_step
    prefill_32k  seq 32,768  gb 32    (inference)       -> serve prefill
    decode_32k   seq 32,768  gb 128   (inference)       -> serve decode (1 new
                                                           token, 32k KV cache)
    long_500k    seq 524,288 gb 1     (long-context)    -> decode; SSM/hybrid
                                                           only (sub-quadratic
                                                           rule); skips noted

``input_specs`` returns weak-type-correct ShapeDtypeStructs for every model
input of the given (arch, shape) — no device allocation (dry-run pattern).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.models.transformer import ArchConfig


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str        # 'train' | 'prefill' | 'decode'


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


def cell_supported(cfg: ArchConfig, shape_name: str) -> Optional[str]:
    """None if (arch, shape) runs; otherwise the skip reason (recorded)."""
    if shape_name == "long_500k" and not cfg.sub_quadratic():
        return "full-attention arch: long_500k skipped per assignment rule"
    return None


def _sd(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg: ArchConfig, shape_name: str) -> Dict[str, jax.ShapeDtypeStruct]:
    """Model inputs for the step function of this (arch, shape) cell."""
    sp = SHAPES[shape_name]
    B, S = sp.global_batch, sp.seq_len
    tok = jnp.int32
    emb = jnp.dtype(cfg.compute_dtype)

    if sp.kind == "train":
        specs = {"tokens": _sd((B, S), tok), "labels": _sd((B, S), tok)}
        if cfg.frontend == "patch":
            specs["patches"] = _sd((B, cfg.frontend_len, cfg.frontend_dim), emb)
        if cfg.frontend == "frame" or cfg.encdec:
            specs["frames"] = _sd((B, cfg.frontend_len, cfg.frontend_dim), emb)
        return specs

    if sp.kind == "prefill":
        specs = {"tokens": _sd((B, S), tok)}
        if cfg.frontend == "patch":
            specs["patches"] = _sd((B, cfg.frontend_len, cfg.frontend_dim), emb)
        if cfg.encdec:
            specs["frames"] = _sd((B, S, cfg.frontend_dim), emb)
        return specs

    # decode: one new token against a seq_len-deep cache
    specs = {"token": _sd((B,), tok), "pos": _sd((), jnp.int32)}
    return specs
