"""kimi-k2-1t-a32b [moe] — trillion-param MoE (paper-table config).

61L d_model=7168 64H (GQA kv=8) d_ff(expert)=2048 vocab=163840,
MoE 384 routed top-8 + 1 shared.  Largest shape census → the primary MILP
load-balance stress case.  Full attention ⇒ long_500k skipped.
ZeRO-3 params + bf16 master/momentum required to fit 16 GB/chip (DESIGN §8).
"""

from repro.models.moe import MoEConfig
from repro.models.transformer import ArchConfig

ARCH_ID = "kimi-k2-1t-a32b"


def config(**overrides) -> ArchConfig:
    base = dict(
        name=ARCH_ID, family="moe",
        n_layers=61, d_model=7168, n_heads=64, n_kv_heads=8,
        d_ff=2048, vocab=163840, head_dim=128,
        moe=MoEConfig(d_model=7168, d_expert=2048, n_experts=384, top_k=8,
                      n_shared=1),
        param_dtype="bfloat16", compute_dtype="bfloat16",
    )
    base.update(overrides)
    return ArchConfig(**base)


def reduced(**overrides) -> ArchConfig:
    base = dict(
        name=ARCH_ID + "-reduced", family="moe",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=64, vocab=257, head_dim=16,
        moe=MoEConfig(d_model=64, d_expert=32, n_experts=4, top_k=2,
                      n_shared=1, capacity_factor=4.0),
        remat=False,
    )
    base.update(overrides)
    return ArchConfig(**base)
