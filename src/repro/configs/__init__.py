"""Architecture registry: the 10 assigned architectures (+ paper workload
analogue via smollm for the end-to-end example).

Usage:  cfg = configs.get("qwen2.5-14b")          # full (dry-run only)
        cfg = configs.get("qwen2.5-14b", reduced=True)   # CPU smoke tests
"""

from repro.configs import (deepseek_v3_671b, hymba_1p5b, kimi_k2_1t,
                           llava_next_mistral_7b, nemotron4_340b,
                           qwen2p5_14b, seamless_m4t_v2, smollm_360m,
                           stablelm_1p6b, xlstm_350m)

_MODULES = (hymba_1p5b, qwen2p5_14b, nemotron4_340b, smollm_360m,
            stablelm_1p6b, deepseek_v3_671b, kimi_k2_1t, xlstm_350m,
            seamless_m4t_v2, llava_next_mistral_7b)

REGISTRY = {m.ARCH_ID: m for m in _MODULES}
ARCH_IDS = tuple(REGISTRY)


def get(arch_id: str, reduced: bool = False, **overrides):
    if arch_id not in REGISTRY:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(REGISTRY)}")
    m = REGISTRY[arch_id]
    return (m.reduced if reduced else m.config)(**overrides)
