"""qwen2.5-14b [dense] — GQA with QKV bias (Qwen2.5 technical report).

48L d_model=5120 40H (GQA kv=8) d_ff=13824 vocab=152064.
Full attention ⇒ long_500k skipped.
"""

from repro.models.transformer import ArchConfig

ARCH_ID = "qwen2.5-14b"


def config(**overrides) -> ArchConfig:
    base = dict(
        name=ARCH_ID, family="dense",
        n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8,
        d_ff=13824, vocab=152064, head_dim=128,
        qkv_bias=True, rope_theta=1e6,
    )
    base.update(overrides)
    return ArchConfig(**base)


def reduced(**overrides) -> ArchConfig:
    base = dict(
        name=ARCH_ID + "-reduced", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=160, vocab=257, head_dim=16, qkv_bias=True,
        remat=False,
    )
    base.update(overrides)
    return ArchConfig(**base)
