"""llava-next-mistral-7b [vlm] — anyres tiling, mistral backbone
(hf:llava-hf/llava-v1.6-mistral-7b-hf).

32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=32000.
The vision frontend is a STUB per the assignment: input_specs() provides
precomputed patch embeddings (B, 2880, 1024) — anyres 4 tiles + base image ×
576 CLIP-L patches — projected by the 2-layer MLP connector.
Full attention ⇒ long_500k skipped.
"""

from repro.models.transformer import ArchConfig

ARCH_ID = "llava-next-mistral-7b"


def config(**overrides) -> ArchConfig:
    base = dict(
        name=ARCH_ID, family="vlm",
        n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
        d_ff=14336, vocab=32000, head_dim=128,
        frontend="patch", frontend_dim=1024, frontend_len=2880,
    )
    base.update(overrides)
    return ArchConfig(**base)


def reduced(**overrides) -> ArchConfig:
    base = dict(
        name=ARCH_ID + "-reduced", family="vlm",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=160, vocab=257, head_dim=16,
        frontend="patch", frontend_dim=32, frontend_len=8,
        remat=False,
    )
    base.update(overrides)
    return ArchConfig(**base)
