"""stablelm-1.6b [dense] — MHA (kv = heads) (hf:stabilityai/stablelm-2-1_6b).

24L d_model=2048 32H (GQA kv=32) d_ff=5632 vocab=100352.
Full attention ⇒ long_500k skipped.
"""

from repro.models.transformer import ArchConfig

ARCH_ID = "stablelm-1.6b"


def config(**overrides) -> ArchConfig:
    base = dict(
        name=ARCH_ID, family="dense",
        n_layers=24, d_model=2048, n_heads=32, n_kv_heads=32,
        d_ff=5632, vocab=100352, head_dim=64,
    )
    base.update(overrides)
    return ArchConfig(**base)


def reduced(**overrides) -> ArchConfig:
    base = dict(
        name=ARCH_ID + "-reduced", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=160, vocab=257, head_dim=16,
        remat=False,
    )
    base.update(overrides)
    return ArchConfig(**base)
