"""seamless-m4t-large-v2 [audio] — encoder-decoder, multimodal (arXiv:2308.11596).

24L d_model=1024 16H (MHA kv=16) d_ff=8192 vocab=256206.
The audio frontend is a STUB per the assignment: input_specs() provides
precomputed frame embeddings (B, S_enc, 1024).  Enc-dec: 24 encoder + 24
decoder layers.  Full attention ⇒ long_500k skipped; decode runs through the
decoder with cross-attention KV cache.
"""

from repro.models.transformer import ArchConfig

ARCH_ID = "seamless-m4t-large-v2"


def config(**overrides) -> ArchConfig:
    base = dict(
        name=ARCH_ID, family="audio",
        n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16,
        d_ff=8192, vocab=256206, head_dim=64,
        encdec=True, n_enc_layers=24,
        frontend="frame", frontend_dim=1024, frontend_len=4096,
    )
    base.update(overrides)
    return ArchConfig(**base)


def reduced(**overrides) -> ArchConfig:
    base = dict(
        name=ARCH_ID + "-reduced", family="audio",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=128, vocab=257, head_dim=16,
        encdec=True, n_enc_layers=2,
        frontend="frame", frontend_dim=32, frontend_len=8,
        remat=False,
    )
    base.update(overrides)
    return ArchConfig(**base)
