"""xlstm-350m [ssm] — sLSTM + mLSTM blocks (arXiv:2405.04517).

24L d_model=1024 4H d_ff=0 (block-internal ff_mult=2) vocab=50304.
Recurrent O(1) state ⇒ runs long_500k.  Layout: one sLSTM block every 8
(21 mLSTM + 3 sLSTM, the paper's [7:1]-style interleave).
"""

from repro.models.transformer import ArchConfig
from repro.models.xlstm import XLSTMConfig

ARCH_ID = "xlstm-350m"


def config(**overrides) -> ArchConfig:
    base = dict(
        name=ARCH_ID, family="ssm",
        n_layers=24, d_model=1024, n_heads=4, n_kv_heads=4,
        d_ff=0, vocab=50304,
        xlstm=XLSTMConfig(d_model=1024, n_heads=4, slstm_every=8,
                          ff_mult=2.0),
    )
    base.update(overrides)
    return ArchConfig(**base)


def reduced(**overrides) -> ArchConfig:
    base = dict(
        name=ARCH_ID + "-reduced", family="ssm",
        n_layers=8, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=0, vocab=257,
        xlstm=XLSTMConfig(d_model=64, n_heads=4, slstm_every=4,
                          ff_mult=2.0),
        remat=False,
    )
    base.update(overrides)
    return ArchConfig(**base)
