"""deepseek-v3-671b [moe] — MLA + 1 shared + 256 routed top-8 (arXiv:2412.19437).

61L d_model=7168 128H d_ff(expert)=2048 vocab=129280.
Deviations from the paper: all 61 layers MoE (paper: first 3 dense);
MTP auxiliary head omitted (training-objective feature, orthogonal to the
optimizer-systems reproduction); sort-based token-choice dispatch (moe.py).
Full attention ⇒ long_500k skipped.  ZeRO-3 + bf16 states at mesh scale.
"""

from repro.models.layers import MLAConfig
from repro.models.moe import MoEConfig
from repro.models.transformer import ArchConfig

ARCH_ID = "deepseek-v3-671b"


def config(**overrides) -> ArchConfig:
    base = dict(
        name=ARCH_ID, family="moe",
        n_layers=61, d_model=7168, n_heads=128, n_kv_heads=128,
        d_ff=2048, vocab=129280,
        attn_kind="mla",
        mla=MLAConfig(d_model=7168, n_heads=128, q_lora_rank=1536,
                      kv_lora_rank=512, qk_nope_dim=128, qk_rope_dim=64,
                      v_head_dim=128),
        moe=MoEConfig(d_model=7168, d_expert=2048, n_experts=256, top_k=8,
                      n_shared=1),
        param_dtype="bfloat16", compute_dtype="bfloat16",
    )
    base.update(overrides)
    return ArchConfig(**base)


def reduced(**overrides) -> ArchConfig:
    base = dict(
        name=ARCH_ID + "-reduced", family="moe",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=64, vocab=257,
        attn_kind="mla",
        mla=MLAConfig(d_model=64, n_heads=4, q_lora_rank=32,
                      kv_lora_rank=16, qk_nope_dim=16, qk_rope_dim=8,
                      v_head_dim=16),
        moe=MoEConfig(d_model=64, d_expert=32, n_experts=4, top_k=2,
                      n_shared=1, capacity_factor=4.0),
        remat=False,
    )
    base.update(overrides)
    return ArchConfig(**base)
