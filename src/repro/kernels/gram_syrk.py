"""Pallas TPU kernel: batched SYRK, G = X Xᵀ, lower-triangle only.

Computes the initial Gram matrix of the Gram Newton-Schulz iteration for a
stack of matrices X of shape (B, m, n) (owner-local slice of a shape group).
G is symmetric by construction, so only blocks (i, j) with j <= i are
computed — the mainloop does half the arithmetic of a general batched GEMM
and the epilogue mirror (ops.py / ref.mirror_lower) reconstructs the dense
output required by the subsequent Gram NS steps (paper §3.3).

Structure mirrors ``symmul.py``: triangular grid via scalar-prefetched (i, j)
tables, fp32 VMEM scratch accumulation, MXU-aligned autotuned block shapes.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.symmul import tri_index_tables
from repro.kernels import tpu_compiler_params


def _syrk_kernel(idx_i, idx_j, xi_ref, xj_ref, o_ref, acc_ref, *, nk: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        xi_ref[0], xj_ref[0],
        dimension_numbers=(((1,), (1,)), ((), ())),  # X_i · X_jᵀ
        preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _done():
        o_ref[0] = acc_ref[...].astype(o_ref.dtype)


def _pad(x: jax.Array, axis: int, mult: int) -> jax.Array:
    rem = (-x.shape[axis]) % mult
    if rem == 0:
        return x
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, rem)
    return jnp.pad(x, pad)


@functools.partial(
    jax.jit,
    static_argnames=("block_m", "block_k", "interpret", "out_dtype"))
def syrk_lower(
    x: jax.Array,
    *,
    block_m: int = 128,
    block_k: int = 128,
    interpret: bool = False,
    out_dtype=None,
) -> jax.Array:
    """Raw lower-triangle G = X Xᵀ for x of shape (B, m, n).

    Returns (B, m, m) with the strict upper triangle UNWRITTEN — callers must
    ``ref.mirror_lower``.
    """
    if x.ndim != 3:
        raise ValueError(f"expected (B, m, n), got {x.shape}")
    batch, m, n = x.shape
    out_dtype = out_dtype or x.dtype
    bm = min(block_m, m)
    bk = min(block_k, n)

    x_p = _pad(_pad(x, 1, bm), 2, bk)
    mp, np_ = x_p.shape[1], x_p.shape[2]
    nb, nk = mp // bm, np_ // bk
    ii, jj = tri_index_tables(nb)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(batch, len(ii), nk),
        in_specs=[
            pl.BlockSpec((1, bm, bk), lambda bi, l, k, ii, jj: (bi, ii[l], k)),
            pl.BlockSpec((1, bm, bk), lambda bi, l, k, ii, jj: (bi, jj[l], k)),
        ],
        out_specs=pl.BlockSpec(
            (1, bm, bm), lambda bi, l, k, ii, jj: (bi, ii[l], jj[l])),
        scratch_shapes=[pltpu.VMEM((bm, bm), jnp.float32)],
    )
    out = pl.pallas_call(
        functools.partial(_syrk_kernel, nk=nk),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((batch, mp, mp), out_dtype),
        interpret=interpret,
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary", "arbitrary")),
        name="gram_syrk",
    )(jnp.asarray(ii), jnp.asarray(jj), x_p, x_p)
    return out[:, :m, :m]
