"""Public jit'd wrappers over the Pallas kernels.

These are the ops the Gram NS iteration (core/gram_ns.py) dispatches to when
``use_kernels=True``.  Each op:

  * accepts arbitrary leading batch dims (flattened internally to one),
  * runs the lower-triangle Pallas kernel (symmul.py / gram_syrk.py),
  * mirrors the strict lower triangle up to reconstruct the dense symmetric
    output the next step consumes (ref.mirror_lower),
  * consults the autotuner cache for block shapes unless explicit
    ``block_m/block_k`` are given.

On this CPU-only container the kernels execute in ``interpret=True`` mode for
correctness validation; on TPU set ``interpret=False`` (the default flows from
GramNSConfig.kernel_interpret).
"""

from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.gram_syrk import syrk_lower
from repro.kernels.symmul import symmul_lower


def _flatten_batch(x):
    lead = x.shape[:-2]
    return x.reshape((-1,) + x.shape[-2:]), lead


def _resolve_blocks(m: int, k: int, block_m: Optional[int],
                    block_k: Optional[int], mode: str, dtype) -> tuple[int, int]:
    if block_m is not None and block_k is not None:
        return block_m, block_k
    from repro.kernels.autotune import lookup  # lazy: avoid import cycle
    bm, bk = lookup(mode, m, k, str(jnp.dtype(dtype)))
    return (block_m or bm, block_k or bk)


def syrk(x, *, block_m: Optional[int] = None, block_k: Optional[int] = None,
         interpret: bool = True, out_dtype=None):
    """G = X Xᵀ (dense symmetric output) for x of shape (..., m, n)."""
    xf, lead = _flatten_batch(x)
    bm, bk = _resolve_blocks(xf.shape[-2], xf.shape[-1], block_m, block_k,
                             "syrk", xf.dtype)
    raw = syrk_lower(xf, block_m=bm, block_k=bk, interpret=interpret,
                     out_dtype=out_dtype)
    return ref.mirror_lower(raw).reshape(lead + raw.shape[-2:])


def symmul(a, b, *, block_m: Optional[int] = None,
           block_k: Optional[int] = None, interpret: bool = True,
           out_dtype=None):
    """C = A B for symmetric commuting A, B of shape (..., m, m)."""
    af, lead = _flatten_batch(a)
    bf, _ = _flatten_batch(b)
    bm, bk = _resolve_blocks(af.shape[-1], af.shape[-1], block_m, block_k,
                             "symmul", af.dtype)
    raw = symmul_lower(af, bf, epilogue="plain", block_m=bm, block_k=bk,
                       interpret=interpret, out_dtype=out_dtype)
    return ref.mirror_lower(raw).reshape(lead + raw.shape[-2:])


def gram_poly(g, a: float, b: float, c: float, *,
              block_m: Optional[int] = None, block_k: Optional[int] = None,
              interpret: bool = True, out_dtype=None):
    """P = aI + bG + cG² with the polynomial fused into the G@G epilogue."""
    gf, lead = _flatten_batch(g)
    bm, bk = _resolve_blocks(gf.shape[-1], gf.shape[-1], block_m, block_k,
                             "gram_poly", gf.dtype)
    raw = symmul_lower(gf, gf, epilogue="gram_poly",
                       coeffs=(float(a), float(b), float(c)),
                       block_m=bm, block_k=bk, interpret=interpret,
                       out_dtype=out_dtype)
    return ref.mirror_lower(raw).reshape(lead + raw.shape[-2:])
