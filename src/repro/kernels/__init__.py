"""Pallas TPU kernels for the DMuon Gram Newton-Schulz execution stack.

Modules:
  symmul     — batched symmetric-output matmul, lower-triangle compute,
               fused polynomial epilogue (the paper's "symmetric Gram kernel")
  gram_syrk  — batched G = X Xᵀ, lower-triangle compute
  ops        — public jit'd wrappers (mirror epilogue, autotune dispatch)
  ref        — pure-jnp oracles used by tests and by the CPU/dry-run path
  autotune   — block-shape search + persistent cache (paper Fig. 6)
"""

from repro.kernels import autotune, ops, ref  # noqa: F401
