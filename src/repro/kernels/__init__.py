"""Pallas TPU kernels for the DMuon Gram Newton-Schulz execution stack.

Modules:
  symmul     — batched symmetric-output matmul, lower-triangle compute,
               fused polynomial epilogue (the paper's "symmetric Gram kernel")
  gram_syrk  — batched G = X Xᵀ, lower-triangle compute
  ops        — public jit'd wrappers (mirror epilogue, autotune dispatch)
  ref        — pure-jnp oracles used by tests and by the CPU/dry-run path
  autotune   — block-shape search + persistent cache (paper Fig. 6)
"""

from jax.experimental.pallas import tpu as _pltpu

# The compiler-params container was renamed across JAX releases
# (TPUCompilerParams -> CompilerParams).  Resolve whichever this JAX
# provides once, here, so every kernel module stays version-agnostic.
CompilerParams = getattr(_pltpu, "CompilerParams", None) \
    or getattr(_pltpu, "TPUCompilerParams")


def tpu_compiler_params(**kwargs):
    """Build pltpu compiler params under either API spelling."""
    return CompilerParams(**kwargs)


from repro.kernels import autotune, ops, ref  # noqa: E402,F401
