"""Pallas TPU kernel: batched symmetric-output matrix multiply.

The Gram Newton-Schulz iteration (core/gram_ns.py) multiplies matrices that
are all polynomials in the initial Gram matrix G₀ — they commute and every
product is symmetric.  This kernel therefore computes **only the block-lower
triangle** of C = A @ B (paper §3.3, "SYRK-style execution path"): the grid
enumerates the ``nb(nb+1)/2`` lower blocks instead of all ``nb²``, nearly
halving both MXU work and output traffic.  The strict upper triangle of the
raw output is unwritten; ``ops.py`` mirrors it (``ref.mirror_lower``).

Two fused epilogue modes (selected statically):

* ``plain``      — C_raw[i,j] = acc
* ``gram_poly``  — C_raw[i,j] = a·I[i,j] + b·G[i,j] + c·acc, computing
  P = aI + bG + cG² directly from the G@G pass, so the polynomial
  evaluation never round-trips HBM (paper: "elementwise operations …
  fused into the same epilogue").

Layout notes (TPU):
  * block shapes are MXU-aligned multiples of 128 chosen by the autotuner
    under a VMEM budget (see kernels/autotune.py);
  * the (i, j) block coordinates of the triangular grid are delivered via
    scalar prefetch (host-precomputed int32 tables) so the index maps stay
    scalar-core friendly;
  * accumulation is fp32 in VMEM scratch regardless of the operand dtype.

Validated on CPU via ``interpret=True`` against ``ref.py`` (tests/test_kernels.py).
"""

from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import tpu_compiler_params


def tri_index_tables(n_blocks: int) -> tuple[np.ndarray, np.ndarray]:
    """Host-side (i, j) coordinates of the block-lower triangle, row-major."""
    ii, jj = [], []
    for i in range(n_blocks):
        for j in range(i + 1):
            ii.append(i)
            jj.append(j)
    return (np.asarray(ii, dtype=np.int32), np.asarray(jj, dtype=np.int32))


def _plain_kernel(idx_i, idx_j, a_ref, b_ref, o_ref, acc_ref, *, nk: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(a_ref[0], b_ref[0],
                            preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _done():
        o_ref[0] = acc_ref[...].astype(o_ref.dtype)


def _gram_poly_kernel(idx_i, idx_j, a_ref, b_ref, g_ref, o_ref, acc_ref, *,
                      nk: int, bm: int, coeffs):
    k = pl.program_id(2)
    l = pl.program_id(1)  # hoisted: program_id is not legal inside pl.when

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(a_ref[0], b_ref[0],
                            preferred_element_type=jnp.float32)

    a_c, b_c, c_c = coeffs
    bi, bj = idx_i[l], idx_j[l]
    rows = bi * bm + jax.lax.broadcasted_iota(jnp.int32, (bm, bm), 0)
    cols = bj * bm + jax.lax.broadcasted_iota(jnp.int32, (bm, bm), 1)
    eye = (rows == cols).astype(jnp.float32)

    @pl.when(k == nk - 1)
    def _done():
        acc = a_c * eye + b_c * g_ref[0].astype(jnp.float32) + c_c * acc_ref[...]
        o_ref[0] = acc.astype(o_ref.dtype)


def _pad_square(x: jax.Array, size: int) -> jax.Array:
    m = x.shape[-1]
    if m == size:
        return x
    return jnp.pad(x, [(0, 0)] * (x.ndim - 2) + [(0, size - m), (0, size - m)])


@functools.partial(
    jax.jit,
    static_argnames=("epilogue", "coeffs", "block_m", "block_k", "interpret",
                     "out_dtype"))
def symmul_lower(
    a: jax.Array,
    b: jax.Array,
    *,
    epilogue: str = "plain",
    coeffs: Optional[tuple] = None,
    block_m: int = 128,
    block_k: int = 128,
    interpret: bool = False,
    out_dtype=None,
) -> jax.Array:
    """Raw lower-triangle product. a, b: (B, m, m). Returns (B, m, m) with the
    strict upper triangle UNWRITTEN — callers must ``ref.mirror_lower``.

    For ``epilogue='gram_poly'``, call with a == b == G and static (a,b,c) in
    ``coeffs``; the output is P = aI + bG + cG² (lower blocks).
    """
    if a.ndim != 3 or b.ndim != 3:
        raise ValueError(f"expected (B, m, m) operands, got {a.shape}, {b.shape}")
    if a.shape != b.shape or a.shape[-1] != a.shape[-2]:
        raise ValueError(f"symmul expects equal square operands, got {a.shape}, {b.shape}")
    if epilogue not in ("plain", "gram_poly"):
        raise ValueError(f"unknown epilogue {epilogue!r}")
    if epilogue == "gram_poly" and (coeffs is None or len(coeffs) != 3):
        raise ValueError("gram_poly epilogue requires static (a, b, c) coeffs")

    batch, m, _ = a.shape
    out_dtype = out_dtype or a.dtype
    bm = min(block_m, m)
    bk = min(block_k, m)
    # Pad both axes to a common multiple of the row- and k-block sizes so the
    # (i, j) block tables index every operand consistently.
    step = math.lcm(bm, bk)
    mp = ((m + step - 1) // step) * step
    a_p = _pad_square(a, mp)
    b_p = _pad_square(b, mp)
    nb, nk = mp // bm, mp // bk
    ii, jj = tri_index_tables(nb)
    n_lower = len(ii)

    in_specs = [
        pl.BlockSpec((1, bm, bk), lambda bi, l, k, ii, jj: (bi, ii[l], k)),
        pl.BlockSpec((1, bk, bm), lambda bi, l, k, ii, jj: (bi, k, jj[l])),
    ]
    operands = [a_p, b_p]
    if epilogue == "gram_poly":
        # G operand for the fused polynomial epilogue, pinned at (i, j).
        in_specs.append(pl.BlockSpec(
            (1, bm, bm), lambda bi, l, k, ii, jj: (bi, ii[l], jj[l])))
        operands.append(a_p)
        kernel = functools.partial(_gram_poly_kernel, nk=nk, bm=bm, coeffs=coeffs)
    else:
        kernel = functools.partial(_plain_kernel, nk=nk)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(batch, n_lower, nk),
        in_specs=in_specs,
        out_specs=pl.BlockSpec(
            (1, bm, bm), lambda bi, l, k, ii, jj: (bi, ii[l], jj[l])),
        scratch_shapes=[pltpu.VMEM((bm, bm), jnp.float32)],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((batch, mp, mp), out_dtype),
        interpret=interpret,
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary", "arbitrary")),
        name=f"symmul_{epilogue}",
    )(jnp.asarray(ii), jnp.asarray(jj), *operands)
    return out[:, :m, :m]
