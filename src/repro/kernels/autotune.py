"""Shape-adaptive kernel autotuning with a persistent cache (paper §3.3, Fig. 6).

The paper's workflow: expand a search space of tile/block/pipeline configs per
workload shape, benchmark candidates on the target hardware, cache the winner
keyed by problem shape + execution mode, and dispatch cached configs on later
invocations.  The TPU analogue of tile/warp scheduling is BlockSpec block
shapes under a VMEM budget with MXU-aligned (multiples of 128 where possible)
dimensions — that is the space searched here.

Two measurement backends:
  * ``measured``   — wall-time the public op (interpret mode on this CPU-only
    container; on a real TPU the same code path times the compiled kernel).
  * ``analytical`` — a TPU roofline scorer (VMEM-resident working set, MXU
    utilization of the block shape, grid overhead) used by the dry-run where
    nothing executes.  This mirrors how the measured-cost load balancer
    (core/load_balance.py) also accepts analytic costs on non-TPU hosts.

The cache is a JSON file keyed by (mode, m, k, dtype); model parameter shapes
are fixed for a whole training run, so tuning cost is paid once (paper: "the
same parameter shapes recur throughout training").
"""

from __future__ import annotations

import json
import os
import threading
import time
from typing import Iterable, Optional

_DEFAULT_CACHE = os.environ.get(
    "DMUON_AUTOTUNE_CACHE",
    os.path.join(os.path.expanduser("~"), ".cache", "dmuon", "autotune.json"))

_VMEM_BYTES = 16 * 1024 * 1024   # per-core VMEM budget (v5e class)
_VMEM_FRACTION = 0.5             # leave room for pipelining double-buffers
_MXU = 128                       # MXU systolic dimension

_lock = threading.Lock()
_memory_cache: dict[str, tuple[int, int]] = {}
_loaded_paths: set[str] = set()


def _key(mode: str, m: int, k: int, dtype: str) -> str:
    return f"{mode}:{m}x{k}:{dtype}"


def _load(path: str) -> None:
    if path in _loaded_paths:
        return
    _loaded_paths.add(path)
    try:
        with open(path) as f:
            data = json.load(f)
        for k, v in data.items():
            _memory_cache.setdefault(k, (int(v[0]), int(v[1])))
    except (OSError, ValueError):
        pass


def _save(path: str) -> None:
    try:
        os.makedirs(os.path.dirname(path), exist_ok=True)
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            json.dump({k: list(v) for k, v in _memory_cache.items()}, f)
        os.replace(tmp, path)
    except OSError:
        pass


def candidate_blocks(m: int, k: int, dtype_bytes: int = 4
                     ) -> Iterable[tuple[int, int]]:
    """Feasible (block_m, block_k) candidates under the VMEM budget.

    Working set per grid step: A (bm×bk) + B (bk×bm) + out/acc (bm×bm),
    double-buffered inputs.  Blocks are MXU-aligned when the problem allows.
    """
    budget = _VMEM_BYTES * _VMEM_FRACTION
    sizes = [s for s in (64, 128, 256, 512, 1024) if s <= max(m, _MXU)]
    if m < 64:
        sizes = [m]
    out = []
    for bm in sizes:
        for bk in sizes:
            if bm > m or bk > max(m, k):
                continue
            ws = (2 * (bm * bk + bk * bm) + 2 * bm * bm) * dtype_bytes
            if ws <= budget:
                out.append((bm, bk))
    return out or [(min(m, 128), min(max(m, k), 128))]


def analytical_score(bm: int, bk: int, m: int, k: int,
                     dtype_bytes: int = 4) -> float:
    """Lower is better.  Models MXU alignment waste + grid dispatch overhead
    + accumulator residency, the TPU counterparts of the paper's tile/pipeline
    search dimensions."""
    pad_m = -m % bm
    pad_k = -k % bk
    waste = ((m + pad_m) * (k + pad_k)) / float(m * k)      # padded FLOP ratio
    align = 1.0 if (bm % _MXU == 0 and bk % _MXU == 0) else 1.3
    nb = (m + bm - 1) // bm
    steps = (nb * (nb + 1) // 2) * ((k + bk - 1) // bk)     # triangular grid
    dispatch = 1.0 + 5e-4 * steps                            # per-step overhead
    # small blocks underfill the MXU; huge blocks limit pipelining overlap
    fill = max(_MXU / bm, 1.0) * max(_MXU / bk, 1.0)
    return waste * align * dispatch * fill


def tune(mode: str, m: int, k: int, dtype: str = "float32", *,
         backend: str = "analytical", batch: int = 1,
         measure_fn=None, cache_path: Optional[str] = None
         ) -> tuple[int, int]:
    """Search candidates and cache the winner.

    ``measure_fn(bm, bk) -> seconds`` overrides the scorer (the CPU test
    harness and, on real hardware, the TPU timer plug in here).
    """
    cache_path = _DEFAULT_CACHE if cache_path is None else cache_path
    key = _key(mode, m, k, dtype)
    with _lock:
        _load(cache_path)
        if key in _memory_cache:
            return _memory_cache[key]

    dtype_bytes = 2 if dtype in ("bfloat16", "float16") else 4
    best, best_score = None, float("inf")
    for bm, bk in candidate_blocks(m, k, dtype_bytes):
        if measure_fn is not None:
            score = measure_fn(bm, bk)
        elif backend == "analytical":
            score = analytical_score(bm, bk, m, k, dtype_bytes)
        else:
            score = _measure_wall(mode, bm, bk, m, k, dtype, batch)
        if score < best_score:
            best, best_score = (bm, bk), score

    with _lock:
        _memory_cache[key] = best
        _save(cache_path)
    return best


def _measure_wall(mode: str, bm: int, bk: int, m: int, k: int,
                  dtype: str, batch: int) -> float:
    """Wall-time the public op (interpret mode on CPU; compiled on TPU)."""
    import jax
    import jax.numpy as jnp
    from repro.kernels import ops
    rng = jax.random.PRNGKey(0)
    if mode == "syrk":
        x = jax.random.normal(rng, (batch, m, k), dtype=jnp.dtype(dtype))
        fn = lambda: ops.syrk(x, block_m=bm, block_k=bk)
    elif mode == "gram_poly":
        g = jax.random.normal(rng, (batch, m, m), dtype=jnp.dtype(dtype))
        g = (g + g.mT) / 2
        fn = lambda: ops.gram_poly(g, 3.0, -4.0, 2.0, block_m=bm, block_k=bk)
    else:
        a = jax.random.normal(rng, (batch, m, m), dtype=jnp.dtype(dtype))
        a = (a + a.mT) / 2
        fn = lambda: ops.symmul(a, a, block_m=bm, block_k=bk)
    fn().block_until_ready()  # compile / warm
    t0 = time.perf_counter()
    fn().block_until_ready()
    return time.perf_counter() - t0


def cached_entry(mode: str, m: int, k: int, dtype: str,
                 cache_path: Optional[str] = None
                 ) -> Optional[tuple[int, int]]:
    """The cached winner for a shape, or None — never tunes or scores."""
    cache_path = _DEFAULT_CACHE if cache_path is None else cache_path
    with _lock:
        _load(cache_path)
        return _memory_cache.get(_key(mode, m, k, dtype))


def lookup(mode: str, m: int, k: int, dtype: str,
           cache_path: Optional[str] = None) -> tuple[int, int]:
    """Cache hit or analytic tune — never measures (safe inside jit tracing)."""
    cache_path = _DEFAULT_CACHE if cache_path is None else cache_path
    key = _key(mode, m, k, dtype)
    with _lock:
        _load(cache_path)
        hit = _memory_cache.get(key)
    if hit is not None:
        return hit
    return tune(mode, m, k, dtype, backend="analytical", cache_path=cache_path)


def plan_shapes(plan) -> list[tuple[str, int, int]]:
    """Every (mode, m, k) kernel launch a dedication plan can produce.

    The Gram NS schedule per (m, n) shape group launches one m×n SYRK (G₀),
    then m×m ``gram_poly`` / ``symmul`` products — so a plan's full kernel
    footprint is three modes per distinct Gram dimension plus one SYRK per
    distinct group shape.
    """
    shapes: set[tuple[str, int, int]] = set()
    for g in plan.groups.values():
        m, n = g.key
        shapes.add(("syrk", m, n))
        shapes.add(("gram_poly", m, m))
        shapes.add(("symmul", m, m))
    return sorted(shapes)


def prewarm_plan(plan, *, dtypes=("float32",), backend: str = "analytical",
                 cache_path: Optional[str] = None) -> int:
    """Pre-warm the persistent cache for every shape in a dedication plan.

    Called at optimizer init (core/api.py): the paper's §3.3 workflow tunes
    once per (mode, shape, dtype) because "the same parameter shapes recur
    throughout training" — after this, ``lookup`` inside the jit'd step never
    falls back to an un-cached tune.  Shapes already in the cache are skipped
    entirely (no re-tune, no re-score, no cache rewrite), so re-initializing
    an optimizer over a warm plan — ``Muon.replace()``, elastic restarts —
    costs nothing.  Returns the number of cache entries covered (hit or
    newly tuned).
    """
    n = 0
    for dt in dtypes:
        for mode, m, k in plan_shapes(plan):
            if cached_entry(mode, m, k, str(dt),
                            cache_path=cache_path) is None:
                tune(mode, m, k, str(dt), backend=backend,
                     cache_path=cache_path)
            n += 1
    return n


def clear_memory_cache() -> None:
    with _lock:
        _memory_cache.clear()
        _loaded_paths.clear()
