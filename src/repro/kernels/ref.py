"""Pure-jnp oracles for the Pallas kernels.

These are the semantic references the kernel tests ``assert_allclose``
against, and also the execution path used on CPU and in the multi-pod
dry-run (Pallas interpret mode unrolls the grid into enormous HLO, so the
dry-run lowers this path and the roofline harness applies the analytic
symmetric-kernel FLOP adjustment — see docs/DESIGN.md §2).

All functions accept arbitrary leading batch dims and accumulate in fp32.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def _bmm(a: jax.Array, b: jax.Array) -> jax.Array:
    out = jax.lax.dot_general(
        a, b,
        dimension_numbers=(((a.ndim - 1,), (b.ndim - 2,)),
                           (tuple(range(a.ndim - 2)), tuple(range(b.ndim - 2)))),
        preferred_element_type=jnp.float32)
    return out.astype(a.dtype)


def syrk_ref(x: jax.Array) -> jax.Array:
    """G = X Xᵀ for X of shape (..., m, n); output (..., m, m), symmetric."""
    return _bmm(x, x.mT)


def symmul_ref(a: jax.Array, b: jax.Array) -> jax.Array:
    """C = A B for symmetric commuting A, B (C symmetric). Shapes (..., m, m)."""
    return _bmm(a, b)


def gram_poly_ref(g: jax.Array, a: float, b: float, c: float) -> jax.Array:
    """P = aI + bG + c(G@G) for symmetric G of shape (..., m, m)."""
    m = g.shape[-1]
    eye = jnp.eye(m, dtype=g.dtype)
    return (a * eye + b * g + c * _bmm(g, g)).astype(g.dtype)


def mirror_lower(c_raw: jax.Array) -> jax.Array:
    """Reconstruct a full symmetric matrix from block-lower-triangular output.

    The Pallas kernels write only blocks (i, j) with j <= i; everything
    strictly above the diagonal is unwritten garbage.  ``tril`` discards it
    and the strict lower triangle is mirrored up.
    """
    lower = jnp.tril(c_raw)
    return lower + jnp.tril(c_raw, -1).mT
