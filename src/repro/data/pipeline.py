"""Deterministic synthetic token pipeline with device sharding + prefetch.

Production framing: the pipeline is keyed by (seed, step) so a restart from a
checkpoint at step k regenerates exactly the batches k, k+1, ... — the
determinism contract fault-tolerant training needs (checkpoint/manager.py
stores the step; nothing else is required to resume the data stream).

Batches are placed with the mesh's DP sharding; a background thread keeps a
bounded prefetch queue ahead of the training loop (host-side analogue of the
paper's overlap discipline: input latency hides under step compute).
"""

from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Iterator, Optional

import jax
import numpy as np


@dataclass
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    # synthetic structure: repeated n-gram process so loss can actually fall
    ngram: int = 3


def _batch_at(cfg: DataConfig, step: int) -> dict:
    """Deterministic batch for ``step`` (pure function — restart-safe)."""
    rng = np.random.default_rng(
        np.random.SeedSequence([cfg.seed, step, 0xD40A]))
    B, S = cfg.global_batch, cfg.seq_len
    # Markov-ish stream: next token depends on previous via a fixed table,
    # with noise — learnable structure for convergence examples.
    table = np.random.default_rng(cfg.seed).integers(
        0, cfg.vocab, size=(cfg.vocab,), dtype=np.int32)
    toks = np.empty((B, S + 1), dtype=np.int32)
    toks[:, 0] = rng.integers(0, cfg.vocab, size=(B,))
    noise = rng.random((B, S))
    rand_toks = rng.integers(0, cfg.vocab, size=(B, S), dtype=np.int32)
    for t in range(S):
        follow = table[toks[:, t]]
        toks[:, t + 1] = np.where(noise[:, t] < 0.75, follow, rand_toks[:, t])
    return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


class Pipeline:
    """Iterator with bounded background prefetch and device placement.

    Checkpointable iterator contract (fault tolerance): ``state()`` returns
    the cursor of the next batch ``__next__`` will yield, as a pytree of
    arrays that rides inside the checkpoint tree (checkpoint/manager.py);
    ``restore(state)`` repositions the stream there, discarding prefetched
    batches.  A resumed run therefore replays batches k, k+1, ... exactly —
    the determinism the unfaulted-vs-restored bit-identity tests rely on.
    """

    def __init__(self, cfg: DataConfig, mesh=None, start_step: int = 0,
                 prefetch: int = 2, sharding=None):
        self.cfg = cfg
        self.mesh = mesh
        self.sharding = sharding
        self.prefetch = prefetch
        self._step = start_step
        self._q: queue.Queue = queue.Queue(maxsize=prefetch)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _place(self, batch: dict) -> dict:
        if self.sharding is None:
            return {k: jax.numpy.asarray(v) for k, v in batch.items()}
        return {k: jax.device_put(v, self.sharding) for k, v in batch.items()}

    def _worker(self):
        step = self._step
        while not self._stop.is_set():
            batch = _batch_at(self.cfg, step)
            while not self._stop.is_set():
                try:
                    self._q.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def __iter__(self) -> Iterator[dict]:
        return self

    def __next__(self) -> dict:
        step, batch = self._q.get()
        self._step = step + 1
        return self._place(batch)

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2)

    # ----------------------------------------------------- checkpoint state

    def state(self) -> dict:
        """Checkpointable cursor: the step of the next batch ``__next__``
        yields.  Prefetched-but-unconsumed batches are deliberately NOT part
        of the state — they are regenerated on restore (purity of
        ``_batch_at``), so the state is one integer however deep the queue.
        """
        return {"data_step": np.asarray(self._step, dtype=np.int64)}

    def restore(self, state: dict) -> None:
        """Reposition the stream at a cursor produced by ``state()`` (possibly
        round-tripped through the checkpoint manager as a device array)."""
        self.seek(int(np.asarray(state["data_step"])))

    def seek(self, step: int) -> None:
        """Repoint the stream at ``step``: stop the prefetch worker, drop the
        queued batches, restart from the new cursor."""
        self.close()
        self._step = step
        self._q = queue.Queue(maxsize=self.prefetch)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()


def batch_for_step(cfg: DataConfig, step: int, sharding=None) -> dict:
    """Direct (no-thread) access — used by tests and the restart check."""
    batch = _batch_at(cfg, step)
    if sharding is not None:
        return {k: jax.device_put(v, sharding) for k, v in batch.items()}
    return {k: jax.numpy.asarray(v) for k, v in batch.items()}
