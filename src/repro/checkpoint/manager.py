"""Shard-aware checkpointing with atomic commit, rotation, async save and
elastic restore (fault-tolerance substrate; docs/DESIGN.md §7).

Layout of one checkpoint:

    <dir>/step_000123.tmp/          # written first
        manifest.json               # tree structure, shapes, dtypes
        <leaf-hash>.shard<i>.npz    # per-process addressable shards with
                                    # their global index slices
    <dir>/step_000123/              # atomic os.replace commit

Restore reassembles each logical array from shard files and re-shards onto
the *current* mesh — the device count / topology may differ from save time
(elastic restart after node failure).  On a single-process CPU container the
shard set is simply the full array; the format is identical.
"""

from __future__ import annotations

import hashlib
import json
import os
import re
import shutil
import threading
from typing import Any, Callable, Optional

import jax
import numpy as np

_STEP_RE = re.compile(r"^step_(\d+)$")


def _path_str(kp) -> str:
    out = []
    for k in kp:
        for attr in ("key", "idx", "name"):
            if hasattr(k, attr):
                out.append(str(getattr(k, attr)))
                break
        else:
            out.append(str(k))
    return "/".join(out)


def _leaf_file(path: str) -> str:
    h = hashlib.sha1(path.encode()).hexdigest()[:16]
    return f"leaf_{h}"


class CheckpointManager:
    def __init__(self, directory: str, *, keep: int = 3,
                 async_save: bool = True):
        self.dir = directory
        self.keep = keep
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    # ------------------------------------------------------------- save

    def save(self, step: int, tree: Any, *, block: bool = False) -> None:
        """Snapshot to host memory synchronously, write/commit (maybe async)."""
        host = []
        flat = jax.tree_util.tree_leaves_with_path(tree)
        manifest = {"step": step, "leaves": {}}
        for kp, leaf in flat:
            path = _path_str(kp)
            if leaf is None:
                manifest["leaves"][path] = {"none": True}
                continue
            arr = jax.device_get(leaf)  # gathers addressable shards
            manifest["leaves"][path] = {
                "shape": list(np.shape(arr)),
                "dtype": str(np.asarray(arr).dtype),
                "file": _leaf_file(path),
            }
            # explicit copy: device_get can be zero-copy on the CPU backend,
            # and the async writer must not race a donated/overwritten buffer
            host.append((path, np.array(arr, copy=True)))
        # structure for exact pytree round-trip (pickle: proto serialization
        # rejects user-defined nodes like the MuonState NamedTuple)
        import pickle
        manifest["treedef"] = pickle.dumps(
            jax.tree_util.tree_structure(tree)).hex()

        def write():
            tmp = os.path.join(self.dir, f"step_{step:09d}.tmp")
            final = os.path.join(self.dir, f"step_{step:09d}")
            os.makedirs(tmp, exist_ok=True)
            for path, arr in host:
                np.savez(os.path.join(
                    tmp, manifest["leaves"][path]["file"] + ".shard0.npz"),
                    data=arr,
                    index=np.asarray([[0, s] for s in arr.shape]))
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.replace(tmp, final)           # atomic commit
            self._rotate()

        if self.async_save and not block:
            self.wait()                       # one in-flight save at a time
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()
        else:
            write()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _rotate(self):
        steps = self.all_steps()
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:09d}"),
                          ignore_errors=True)

    # ---------------------------------------------------------- restore

    def all_steps(self):
        out = []
        for name in os.listdir(self.dir):
            m = _STEP_RE.match(name)
            if m and not name.endswith(".tmp"):
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: Optional[int] = None, *, like: Any = None,
                shard_fn: Optional[Callable[[str, np.ndarray], Any]] = None
                ) -> Any:
        """Rebuild the pytree saved at ``step`` (default: latest).

        ``like``: optional pytree of the same structure whose shardings the
        restored arrays adopt (elastic restore onto the *current* mesh).
        ``shard_fn(path, array)`` overrides placement per leaf.
        """
        self.wait()
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.dir}")
        d = os.path.join(self.dir, f"step_{step:09d}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        import pickle
        treedef = pickle.loads(bytes.fromhex(manifest["treedef"]))

        like_leaves = (jax.tree_util.tree_leaves_with_path(like)
                       if like is not None else None)
        like_map = ({_path_str(kp): l for kp, l in like_leaves}
                    if like_leaves else {})

        leaves = []
        for path in _manifest_paths_in_order(manifest, treedef):
            meta = manifest["leaves"][path]
            if meta.get("none"):
                leaves.append(None)
                continue
            arr = _assemble(d, meta)
            if shard_fn is not None:
                leaves.append(shard_fn(path, arr))
            elif path in like_map and hasattr(like_map[path], "sharding"):
                leaves.append(jax.device_put(arr, like_map[path].sharding))
            else:
                dev = jax.numpy.asarray(arr)
                # x64-disabled jax silently narrows int64/float64 (e.g. the
                # data cursor); keep such leaves as host arrays so the
                # round-trip stays bit-exact
                leaves.append(arr if str(dev.dtype) != meta["dtype"] else dev)
        return jax.tree_util.tree_unflatten(treedef, leaves)


def _assemble(d: str, meta: dict) -> np.ndarray:
    """Reassemble a logical array from its shard files."""
    shape = tuple(meta["shape"])
    out = None
    i = 0
    while True:
        fp = os.path.join(d, f"{meta['file']}.shard{i}.npz")
        if not os.path.exists(fp):
            break
        with np.load(fp) as z:
            data, index = z["data"], z["index"]
        if i == 0 and tuple(data.shape) == shape:
            return data.astype(meta["dtype"])
        if out is None:
            out = np.zeros(shape, dtype=meta["dtype"])
        sl = tuple(slice(int(a), int(a) + int(b)) for a, b in index)
        out[sl] = data
        i += 1
    if out is None:
        raise FileNotFoundError(fp)
    return out


def _manifest_paths_in_order(manifest: dict, treedef):
    """Leaf paths in treedef order (manifest dict preserves insertion order,
    which matches tree_leaves_with_path order at save time)."""
    return list(manifest["leaves"].keys())
