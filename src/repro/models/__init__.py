"""Model zoo: pure-JAX init/apply model definitions for the assigned archs.

``model_fns(cfg)`` dispatches to the right backbone module (decoder-only
transformer.py or encoder-decoder encdec.py); both expose the same surface:
init / forward / prefill / decode_step.
"""

from repro.models.transformer import ArchConfig  # noqa: F401


def model_fns(cfg):
    if cfg.encdec:
        from repro.models import encdec
        return encdec
    from repro.models import transformer
    return transformer
