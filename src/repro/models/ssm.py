"""Selective state-space mixer (Mamba-style), used by the hymba hybrid blocks.

Faithful-in-structure selective SSM:
    x -> in_proj -> (xz): x branch conv1d + SiLU, gated by z branch
    dt, B, C from x_proj;  h_{t+1} = exp(A·dt)·h_t + dt·B·x_t;  y = C·h + D·x

The recurrence runs as an associative scan over time (parallel prefix on
TPU), giving O(S) work — this is what qualifies the hybrid archs for the
long_500k shape.  Decode keeps (conv_state, ssm_state) per layer.

Param naming: conv kernels / A_log / dt_bias / D ("skip") are excluded from
Muon by the dedication name rules; in/x/dt/out projections are Muon matrices.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_model: int
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: Optional[int] = None

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def dtr(self) -> int:
        return self.dt_rank or max(16, self.d_model // 16)


def ssm_init(key, cfg: SSMConfig, dtype=jnp.float32):
    ks = jax.random.split(key, 6)
    di, ds, dtr = cfg.d_inner, cfg.d_state, cfg.dtr
    return {
        "in_proj": layers.linear_init(ks[0], cfg.d_model, 2 * di, dtype=dtype),
        "conv_w": (jax.random.normal(ks[1], (cfg.d_conv, di), jnp.float32)
                   * 0.1).astype(dtype),
        "x_proj": layers.linear_init(ks[2], di, dtr + 2 * ds, dtype=dtype),
        "dt_proj": layers.linear_init(ks[3], dtr, di, dtype=dtype),
        "dt_bias": jnp.zeros((di,), dtype),
        "a_log": jnp.log(jnp.broadcast_to(
            jnp.arange(1, ds + 1, dtype=jnp.float32), (di, ds))).astype(dtype),
        "d_skip": jnp.ones((di,), dtype),
        "out_proj": layers.linear_init(ks[4], di, cfg.d_model, dtype=dtype),
    }


def _conv1d(w: jax.Array, x: jax.Array,
            state: Optional[jax.Array] = None) -> Tuple[jax.Array, jax.Array]:
    """Depthwise causal conv. x: (B,S,di), w: (K,di).
    state: (B,K-1,di) trailing context. Returns (y, new_state)."""
    K = w.shape[0]
    B, S, di = x.shape
    if state is None:
        state = jnp.zeros((B, K - 1, di), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)
    y = sum(xp[:, i:i + S, :] * w[i].astype(x.dtype) for i in range(K))
    return y, xp[:, -(K - 1):, :]


def _selective_scan(a_bar, bx):
    """h_t = a_bar_t * h_{t-1} + bx_t via associative scan over axis 1.
    a_bar, bx: (B, S, di, ds)."""
    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2
    a, b = jax.lax.associative_scan(combine, (a_bar, bx), axis=1)
    return b


def ssm(p, cfg: SSMConfig, x: jax.Array, *,
        state: Optional[Tuple[jax.Array, jax.Array]] = None):
    """x: (B,S,d). state = (conv_state, h) for decode. Returns (y, state)."""
    B, S, _ = x.shape
    di, ds = cfg.d_inner, cfg.d_state
    xz = layers.linear(p["in_proj"], x)
    xs, z = jnp.split(xz, 2, axis=-1)

    conv_state = state[0] if state is not None else None
    xs, new_conv = _conv1d(p["conv_w"], xs, conv_state)
    xs = jax.nn.silu(xs)

    dbc = layers.linear(p["x_proj"], xs)
    dt, Bc, Cc = jnp.split(dbc, [cfg.dtr, cfg.dtr + ds], axis=-1)
    dt = jax.nn.softplus(layers.linear(p["dt_proj"], dt)
                         + p["dt_bias"].astype(x.dtype))        # (B,S,di)
    A = -jnp.exp(p["a_log"].astype(jnp.float32))                 # (di,ds)

    a_bar = jnp.exp(dt.astype(jnp.float32)[..., None] * A)       # (B,S,di,ds)
    bx = (dt.astype(jnp.float32) * xs.astype(jnp.float32))[..., None] \
        * Bc.astype(jnp.float32)[..., None, :]                   # (B,S,di,ds)

    if state is not None:   # seed the scan with the carried state
        h0 = state[1]                                            # (B,di,ds)
        bx = bx.at[:, 0].add(a_bar[:, 0] * h0)
    h = _selective_scan(a_bar, bx)                               # (B,S,di,ds)
    y = jnp.einsum("bsdn,bsn->bsd", h, Cc.astype(jnp.float32))
    y = (y + xs.astype(jnp.float32) * p["d_skip"].astype(jnp.float32))
    y = y.astype(x.dtype) * jax.nn.silu(z)
    out = layers.linear(p["out_proj"], y)
    new_state = (new_conv, h[:, -1])
    return out, new_state


def ssm_init_state(cfg: SSMConfig, batch: int, dtype=jnp.float32):
    return (jnp.zeros((batch, cfg.d_conv - 1, cfg.d_inner), dtype),
            jnp.zeros((batch, cfg.d_inner, cfg.d_state), jnp.float32))
