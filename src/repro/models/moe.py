"""Mixture-of-Experts FFN with top-k routing (DeepSeek-V3 / Kimi-K2 style).

Dispatch/combine-einsum implementation (MaxText-style) so the expert matmuls
lower to dense einsums shardable over the 'model' axis (expert parallelism):
tokens are routed to ``top_k`` experts under a capacity factor; shared
experts (DeepSeek's "1 shared") run densely on every token.

Param leaves:
  router_w                       (d, E)        — AdamW (excluded by name)
  experts/{gate,up,down}_proj/w  (E, d, d_ff)  — Muon (E matrices per layer)
  shared/{gate,up,down}_proj/w   (d, s*d_ff)   — Muon
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import layers


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    d_model: int
    d_expert: int                 # per-expert FFN width
    n_experts: int                # routed experts
    top_k: int
    n_shared: int = 0             # shared (always-on) experts
    capacity_factor: float = 1.25
    router_noise: float = 0.0


def moe_init(key, cfg: MoEConfig, dtype=jnp.float32):
    ks = jax.random.split(key, 8)
    d, dff, E = cfg.d_model, cfg.d_expert, cfg.n_experts
    scale = 1.0 / math.sqrt(d)

    def ew(k, din, dout):
        return (jax.random.normal(k, (E, din, dout), jnp.float32)
                * (1.0 / math.sqrt(din))).astype(dtype)

    p = {
        "router_w": (jax.random.normal(ks[0], (d, E), jnp.float32)
                     * scale).astype(dtype),
        "experts": {
            "gate_proj": {"w": ew(ks[1], d, dff)},
            "up_proj": {"w": ew(ks[2], d, dff)},
            "down_proj": {"w": ew(ks[3], dff, d)},
        },
    }
    if cfg.n_shared:
        p["shared"] = layers.mlp_init(ks[4], d, cfg.n_shared * dff, "swiglu",
                                      dtype)
    return p


def moe(p, cfg: MoEConfig, x: jax.Array) -> jax.Array:
    """x: (B, S, d) -> (B, S, d).

    Exact token-choice top-k (DeepSeek semantics) with **sort-based
    dispatch**: the (token, k) assignments are sorted by expert id, ranked
    within their expert segment, and scattered into per-expert capacity
    buffers — O(T·K) memory (one sort + two gathers + one scatter), never a
    (T, K, E, cap) one-hot, so it scales to million-token batches.
    Assignments beyond an expert's capacity C = ceil(cf·T·K/E) are dropped
    (standard capacity semantics).  In the no-drop regime routing depends
    only on the token itself, so decode is autoregressive-consistent with
    training — which tests/test_arch_smoke.py checks.
    """
    B, S, d = x.shape
    T = B * S
    xt = x.reshape(T, d)
    E, K = cfg.n_experts, cfg.top_k
    cap = max(1, min(int(math.ceil(cfg.capacity_factor * T * K / E)), T))

    logits = layers.dot(xt, p["router_w"]).astype(jnp.float32)    # (T, E)
    probs = jax.nn.softmax(logits, -1)
    gates, idx = jax.lax.top_k(probs, K)                          # (T, K)
    gates = gates / jnp.sum(gates, -1, keepdims=True)

    # ---- sort-based capacity dispatch -------------------------------------
    flat_e = idx.reshape(T * K)                                   # expert ids
    flat_t = jnp.repeat(jnp.arange(T), K)                         # token ids
    flat_g = gates.reshape(T * K)
    order = jnp.argsort(flat_e, stable=True)
    e_sorted = flat_e[order]
    t_sorted = flat_t[order]
    g_sorted = flat_g[order]
    counts = jnp.bincount(flat_e, length=E)                       # (E,)
    seg_start = jnp.concatenate([jnp.zeros((1,), counts.dtype),
                                 jnp.cumsum(counts)[:-1]])
    rank = jnp.arange(T * K) - seg_start[e_sorted]                # within-seg
    keep = rank < cap
    dest = jnp.where(keep, e_sorted * cap + rank, E * cap)        # drop slot

    xe_flat = jnp.zeros((E * cap + 1, d), xt.dtype).at[dest].set(
        jnp.take(xt, t_sorted, axis=0))
    xe = xe_flat[:-1].reshape(E, cap, d)

    we_g = p["experts"]["gate_proj"]["w"].astype(xt.dtype)
    we_u = p["experts"]["up_proj"]["w"].astype(xt.dtype)
    we_d = p["experts"]["down_proj"]["w"].astype(xt.dtype)
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, we_g)) * \
        jnp.einsum("ecd,edf->ecf", xe, we_u)
    ye = jnp.einsum("ecf,efd->ecd", h, we_d).reshape(E * cap, d)  # (E*cap,d)

    back = jnp.take(jnp.concatenate([ye, jnp.zeros((1, d), ye.dtype)]),
                    jnp.where(keep, dest, E * cap), axis=0)       # (T*K, d)
    y = jnp.zeros((T, d), ye.dtype).at[t_sorted].add(
        back * g_sorted[:, None].astype(ye.dtype) *
        keep[:, None].astype(ye.dtype))

    if "shared" in p:
        y = y + layers.mlp(p["shared"], xt, "swiglu")
    return y.reshape(B, S, d)


def aux_load_balance_loss(p, cfg: MoEConfig, x: jax.Array) -> jax.Array:
    """Switch-style auxiliary loss (f·P), available to training configs."""
    B, S, d = x.shape
    xt = x.reshape(-1, d)
    logits = layers.dot(xt, p["router_w"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, -1)
    _, idx = jax.lax.top_k(probs, cfg.top_k)
    frac = jnp.mean(jax.nn.one_hot(idx, cfg.n_experts, dtype=jnp.float32),
                    axis=(0, 1))
    pmean = jnp.mean(probs, axis=0)
    return cfg.n_experts * jnp.sum(frac * pmean)
