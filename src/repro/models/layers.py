"""Model-zoo building blocks, pure functional JAX (no flax).

Every layer is an (init, apply) pair; parameters are plain dicts whose key
paths drive both Muon dedication (core/dedication.py name rules) and the
TP sharding rules (models/sharding.py).  All matmuls run in the configured
compute dtype with fp32 accumulation; params are created in ``param_dtype``.

Conventions:
  * linear weights are stored (in_dim, out_dim) — activations @ W
  * stacked-layer leaves carry a leading L dim (built by vmap'd init),
    consumed by lax.scan in the backbones
  * attention caches are preallocated (B, S_max, kv, hd) with
    dynamic_update_slice writes at the decode position
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

Params = Dict[str, Any]


# ---------------------------------------------------------------- utilities

def dot(x: jax.Array, w: jax.Array) -> jax.Array:
    """x @ w with fp32 accumulation, output in x.dtype."""
    return jax.lax.dot_general(
        x, w.astype(x.dtype),
        dimension_numbers=(((x.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32).astype(x.dtype)


def linear_init(key, d_in: int, d_out: int, *, bias: bool = False,
                dtype=jnp.float32, scale: Optional[float] = None) -> Params:
    scale = 1.0 / math.sqrt(d_in) if scale is None else scale
    p = {"w": (jax.random.normal(key, (d_in, d_out), jnp.float32)
               * scale).astype(dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def linear(p: Params, x: jax.Array) -> jax.Array:
    y = dot(x, p["w"])
    if "b" in p:
        y = y + p["b"].astype(y.dtype)
    return y


def rmsnorm_init(d: int, dtype=jnp.float32) -> Params:
    return {"norm_scale": jnp.ones((d,), dtype)}


def rmsnorm(p: Params, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * p["norm_scale"].astype(jnp.float32)).astype(x.dtype)


def embed_init(key, vocab: int, d: int, dtype=jnp.float32) -> Params:
    return {"embedding": (jax.random.normal(key, (vocab, d), jnp.float32)
                          * 0.02).astype(dtype)}


def embed(p: Params, tokens: jax.Array) -> jax.Array:
    return jnp.take(p["embedding"], tokens, axis=0)


def unembed(p: Params, x: jax.Array) -> jax.Array:
    """Tied or untied output head: logits in fp32."""
    return jax.lax.dot_general(
        x, p["embedding"].astype(x.dtype),
        dimension_numbers=(((x.ndim - 1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)


# ------------------------------------------------------------------- rotary

def rope_freqs(head_dim: int, max_pos: int, theta: float = 10000.0):
    inv = 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                           / head_dim))
    t = jnp.arange(max_pos, dtype=jnp.float32)
    ang = jnp.outer(t, inv)                       # (S, hd/2)
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x: (..., S, H, hd); cos/sin: (S, hd/2) already position-gathered."""
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    cos = cos[..., :, None, :]
    sin = sin[..., :, None, :]
    return jnp.concatenate([x1 * cos - x2 * sin,
                            x1 * sin + x2 * cos], axis=-1).astype(x.dtype)


# ---------------------------------------------------------------- attention

@dataclasses.dataclass(frozen=True)
class AttnConfig:
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    sliding_window: Optional[int] = None   # None = full causal
    causal: bool = True                    # False for encoder / cross attn
    # sequence-sharded attention: when the head counts do not divide the
    # 'model' axis, GSPMD replicates the whole attention computation over it;
    # pinning q/output to (batch_axes, seq_axis) shards the score/AV einsums
    # over the sequence instead (k/v gathered once per layer).
    batch_axes: Optional[tuple] = None
    seq_axis: Optional[str] = None


def attention_init(key, cfg: AttnConfig, dtype=jnp.float32) -> Params:
    ks = jax.random.split(key, 4)
    H, KV, hd, d = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim, cfg.d_model
    return {
        "q_proj": linear_init(ks[0], d, H * hd, bias=cfg.qkv_bias, dtype=dtype),
        "k_proj": linear_init(ks[1], d, KV * hd, bias=cfg.qkv_bias, dtype=dtype),
        "v_proj": linear_init(ks[2], d, KV * hd, bias=cfg.qkv_bias, dtype=dtype),
        "o_proj": linear_init(ks[3], H * hd, d, dtype=dtype),
    }


_Q_CHUNK = 1024
_KV_CHUNK = 1024


def _pick_chunk(n: int, target: int) -> int:
    """Largest divisor of n that is <= target."""
    best = 1
    for c in range(1, min(n, target) + 1):
        if n % c == 0:
            best = c
    return best


def _block_mask(qpos, kpos, *, causal, window, window_enabled):
    """Boolean mask from absolute positions, built on the fly.  qpos is
    (qlen,) — or (B, qlen) when decode rows sit at per-slot positions
    (continuous batching) — giving a (qlen, klen) / (B, qlen, klen) mask."""
    if not causal:
        return None
    ok = qpos[..., :, None] >= kpos
    if window is not None:
        okw = ok & (kpos > qpos[..., :, None] - window)
        if window_enabled is None:
            ok = okw
        else:  # traced per-layer flag (uniform-scan hybrid blocks)
            ok = jnp.where(window_enabled, okw, ok)
    return ok


def _paged_write(leaf, new, block_table, pos, block_size):
    """Scatter new K/V rows into a paged pool leaf.

    leaf: (P, bs, ...) physical block pool; new: (B, S, ...) freshly
    projected rows.  Vector ``pos`` (decode, S == 1): row b writes at
    physical block ``table[b, pos[b] // bs]`` offset ``pos[b] % bs``.
    Scalar ``pos`` (chunked prefill, B == 1): the S chunk rows write at
    logical positions pos + arange(S) through row 0's table.  Live block
    tables are injective (paged.BlockPool), so scatter indices never
    collide across slots; free slots idle on the reserved null block 0,
    which no live table ever maps."""
    if jnp.ndim(pos) == 0:
        p = pos + jnp.arange(new.shape[1])
        pb = block_table[0, p // block_size]
        return leaf.at[pb, p % block_size].set(new[0].astype(leaf.dtype))
    pb = jnp.take_along_axis(block_table, (pos // block_size)[:, None],
                             axis=1)[:, 0]
    return leaf.at[pb, pos % block_size].set(new[:, 0].astype(leaf.dtype))


def _paged_read(leaf, block_table):
    """Gather a slot-contiguous (B, W*bs, ...) sequence view from the
    (P, bs, ...) pool: logical block j of row b is ``leaf[table[b, j]]``.
    Entries past a slot's allocated length point at the null block; the
    causal mask (kpos <= qpos) guarantees they are never attended."""
    B, W = block_table.shape
    g = leaf[block_table]                       # (B, W, bs, ...)
    return g.reshape((B, W * leaf.shape[1]) + leaf.shape[2:])


def _sdpa(q, k, v, *, scale, qpos=None, kpos=None, causal=False,
          window=None, window_enabled=None, q_one_block=False):
    """q: (B,S,H,hd); k,v: (B,T,KV,·); GQA by head-group repetition.

    Long sequences take the chunked online-softmax path (flash-attention
    pattern: O(S·chunk) memory instead of O(S·T) materialized probabilities —
    the TPU-native memory discipline the 32k/500k shapes require).  Masks are
    never materialized at (S, T): they are rebuilt per block from positions.
    """
    B, S, H, hd = q.shape
    T = k.shape[1]
    KV = k.shape[2]
    rep = H // KV
    hv = v.shape[-1]
    qg = q.reshape(B, S, KV, rep, hd)
    if qpos is None:
        qpos = jnp.arange(S)
    if kpos is None:
        kpos = jnp.arange(T)

    if S > _Q_CHUNK and T > _KV_CHUNK:
        out = _chunked_sdpa(qg, k, v, scale, qpos, kpos, causal, window,
                            window_enabled, q_one_block=q_one_block)
        return out.reshape(B, S, H, hv).astype(q.dtype)

    logits = jnp.einsum("bsgrh,btgh->bgrst", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    mask = _block_mask(qpos, kpos, causal=causal, window=window,
                       window_enabled=window_enabled)
    if mask is not None:
        # (S,T) shared positions, or (B,S,T) per-row decode positions
        mask = mask[None, None, None] if mask.ndim == 2 \
            else mask[:, None, None]
        logits = jnp.where(mask, logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bgrst,btgh->bsgrh", probs, v.astype(jnp.float32))
    # v's head dim may differ from q/k's (MLA: v_head_dim != qk dims)
    return out.reshape(B, S, H, hv).astype(q.dtype)


def _chunked_sdpa(qg, k, v, scale, qpos, kpos, causal, window,
                  window_enabled, q_one_block=False):
    """Online-softmax attention: lax.map over query blocks × lax.scan over
    KV blocks, fp32 running (max, denom, acc).

    ``q_one_block``: keep the whole query axis as a single block (scan only
    over KV).  Used when q is sequence-sharded over 'model' — lax.map over a
    sharded block axis would be a *sequential* scan over a sharded dim,
    which silently replicates (docs/DESIGN.md §9, qwen prefill)."""
    B, S, G, R, hd = qg.shape
    T = k.shape[1]
    hv = v.shape[-1]
    qc = S if q_one_block else _pick_chunk(S, _Q_CHUNK)
    kc = _pick_chunk(T, _KV_CHUNK)
    nq, nk = S // qc, T // kc

    qb = jnp.moveaxis(qg.reshape(B, nq, qc, G, R, hd), 1, 0)
    qpb = qpos.reshape(nq, qc)
    kb = jnp.moveaxis(k.reshape(B, nk, kc, G, hd), 1, 0)
    vb = jnp.moveaxis(v.reshape(B, nk, kc, G, hv), 1, 0)
    kpb = kpos.reshape(nk, kc)

    def q_block(args):
        q_i, qpos_i = args

        def kv_step(carry, xs):
            m, l, acc = carry
            k_j, v_j, kpos_j = xs
            logits = jnp.einsum("bqgrh,bkgh->bqgrk",
                                q_i.astype(jnp.float32),
                                k_j.astype(jnp.float32)) * scale
            ok = _block_mask(qpos_i, kpos_j, causal=causal, window=window,
                             window_enabled=window_enabled)
            if ok is not None:
                logits = jnp.where(ok[None, :, None, None, :], logits, -1e30)
            m_new = jnp.maximum(m, logits.max(-1))
            p = jnp.exp(logits - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l = l * corr + p.sum(-1)
            # probabilities cross to the AV product in the value dtype
            # (bf16 on TPU) with fp32 accumulation — halves the dominant
            # probs traffic of the prefill cells; a no-op under fp32 compute
            acc = acc * corr[..., None] + jnp.einsum(
                "bqgrk,bkgh->bqgrh", p.astype(v_j.dtype), v_j,
                preferred_element_type=jnp.float32)
            return (m_new, l, acc), None

        init = (jnp.full((B, qc, G, R), -1e30, jnp.float32),
                jnp.zeros((B, qc, G, R), jnp.float32),
                jnp.zeros((B, qc, G, R, hv), jnp.float32))
        (m, l, acc), _ = jax.lax.scan(kv_step, init, (kb, vb, kpb))
        return acc / jnp.maximum(l, 1e-30)[..., None]

    out = jax.lax.map(q_block, (qb, qpb))          # (nq, B, qc, G, R, hv)
    return jnp.moveaxis(out, 0, 1).reshape(B, S, G, R, hv)


def attention(p: Params, cfg: AttnConfig, x: jax.Array, *,
              xk: Optional[jax.Array] = None,
              cache: Optional[Tuple[jax.Array, jax.Array]] = None,
              pos: Optional[jax.Array] = None,
              rope_cs: Optional[Tuple[jax.Array, jax.Array]] = None,
              window_enabled: Optional[jax.Array] = None,
              static_cache: bool = False,
              block_table: Optional[jax.Array] = None):
    """Self (xk=None) or cross attention with optional KV cache.

    cache: (k_cache, v_cache) of (B, S_max, KV, hd); pos: write position —
    a scalar shared by every row (prefill / lockstep decode) or a (B,)
    vector of per-row positions (slot-based continuous batching, S == 1).
    window_enabled: traced bool selecting the sliding window mask at runtime
    (uniform-scan hybrid layers).  static_cache: use the cache as-is without
    recomputing/updating K,V (decode-time cross attention over precomputed
    encoder KV).
    block_table: (B, W) int32 map of logical cache blocks to physical pool
    blocks — the cache leaves are then (P, bs, KV, hd) pools shared by every
    row, written through ``_paged_write`` and read back as a gathered
    (B, W·bs, KV, hd) view (paged KV, docs/DESIGN.md §12).
    Returns (out, new_cache).
    """
    B, S, _ = x.shape
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = linear(p["q_proj"], x).reshape(B, S, H, hd)

    if static_cache:
        assert cache is not None
        k, v = cache
        out = _sdpa(q, k, v, scale=1.0 / math.sqrt(hd))
        return linear(p["o_proj"], out.reshape(B, S, H * hd)), cache

    src = x if xk is None else xk
    k = linear(p["k_proj"], src).reshape(B, src.shape[1], KV, hd)
    v = linear(p["v_proj"], src).reshape(B, src.shape[1], KV, hd)

    if rope_cs is not None and xk is None:
        cos_q, sin_q, cos_k, sin_k = rope_cs
        q = apply_rope(q, cos_q, sin_q)
        k = apply_rope(k, cos_k, sin_k)

    new_cache = None
    if cache is not None and block_table is not None:
        assert xk is None, "paged cache is a self-attention path"
        kc, vc = cache                       # (P, bs, KV, hd) pools
        bs = kc.shape[1]
        kc = _paged_write(kc, k, block_table, pos, bs)
        vc = _paged_write(vc, v, block_table, pos, bs)
        new_cache = (kc, vc)
        k = _paged_read(kc, block_table)
        v = _paged_read(vc, block_table)
    elif cache is not None:
        kc, vc = cache
        if xk is None:  # self-attn decode/prefill cache update
            if jnp.ndim(pos) == 0:
                kc = jax.lax.dynamic_update_slice(kc, k.astype(kc.dtype),
                                                  (0, pos, 0, 0))
                vc = jax.lax.dynamic_update_slice(vc, v.astype(vc.dtype),
                                                  (0, pos, 0, 0))
            else:  # per-row slot positions: scatter one row each
                assert S == 1, "vector pos is a single-token decode path"
                rows = jnp.arange(B)
                kc = kc.at[rows, pos].set(k[:, 0].astype(kc.dtype))
                vc = vc.at[rows, pos].set(v[:, 0].astype(vc.dtype))
        k, v = kc, vc
        new_cache = (kc, vc)

    T = k.shape[1]
    if cfg.seq_axis is not None and S > 1:
        from jax.sharding import PartitionSpec as _P
        pin = _P(cfg.batch_axes, cfg.seq_axis, None, None)
        q = jax.lax.with_sharding_constraint(q, pin)
        # k/v replicated over the seq axis (each q block reads all of them);
        # otherwise GSPMD shards the contracting head_dim and emits an
        # all-reduce per attention block (docs/DESIGN.md §9, qwen prefill)
        kv_pin = _P(cfg.batch_axes, None, None, None)
        k = jax.lax.with_sharding_constraint(k, kv_pin)
        v = jax.lax.with_sharding_constraint(v, kv_pin)
    seq_pinned = cfg.seq_axis is not None and S > 1
    if not cfg.causal or xk is not None:
        out = _sdpa(q, k, v, scale=1.0 / math.sqrt(hd),
                    q_one_block=seq_pinned)
    else:
        offset = pos if pos is not None else 0
        qpos = offset[:, None] + jnp.arange(S) if jnp.ndim(offset) == 1 \
            else offset + jnp.arange(S)
        out = _sdpa(q, k, v, scale=1.0 / math.sqrt(hd),
                    qpos=qpos, kpos=jnp.arange(T), causal=True,
                    window=cfg.sliding_window,
                    window_enabled=window_enabled,
                    q_one_block=seq_pinned)
    if cfg.seq_axis is not None and S > 1:
        out = jax.lax.with_sharding_constraint(
            out, _P(cfg.batch_axes, cfg.seq_axis, None, None))
    out = linear(p["o_proj"], out.reshape(B, S, H * hd))
    return out, new_cache


# -------------------------------------------------------- MLA (DeepSeek-V3)

@dataclasses.dataclass(frozen=True)
class MLAConfig:
    d_model: int
    n_heads: int
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128
    rope_theta: float = 10000.0


def mla_init(key, cfg: MLAConfig, dtype=jnp.float32) -> Params:
    ks = jax.random.split(key, 7)
    H = cfg.n_heads
    qh = cfg.qk_nope_dim + cfg.qk_rope_dim
    return {
        "q_a_proj": linear_init(ks[0], cfg.d_model, cfg.q_lora_rank, dtype=dtype),
        "q_a_norm": rmsnorm_init(cfg.q_lora_rank, dtype),
        "q_b_proj": linear_init(ks[1], cfg.q_lora_rank, H * qh, dtype=dtype),
        "kv_a_proj": linear_init(ks[2], cfg.d_model,
                                 cfg.kv_lora_rank + cfg.qk_rope_dim, dtype=dtype),
        "kv_a_norm": rmsnorm_init(cfg.kv_lora_rank, dtype),
        "kv_b_proj": linear_init(ks[3], cfg.kv_lora_rank,
                                 H * (cfg.qk_nope_dim + cfg.v_head_dim),
                                 dtype=dtype),
        "o_proj": linear_init(ks[4], H * cfg.v_head_dim, cfg.d_model,
                              dtype=dtype),
    }


def mla_attention(p: Params, cfg: MLAConfig, x: jax.Array, *,
                  cache: Optional[Tuple[jax.Array, jax.Array]] = None,
                  pos: Optional[jax.Array] = None,
                  rope_cs=None,
                  block_table: Optional[jax.Array] = None):
    """Multi-head Latent Attention.  Cache holds (c_kv, k_rope): the latent
    (B, S_max, kv_lora) plus shared rope key (B, S_max, 1, rope_dim) — the
    memory saving that defines MLA.  With ``block_table`` both leaves are
    (P, bs, ...) pools indirected per row, same contract as attention()."""
    B, S, _ = x.shape
    H = cfg.n_heads
    nd, rd, vd = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim

    q = linear(p["q_b_proj"], rmsnorm(p["q_a_norm"], linear(p["q_a_proj"], x)))
    q = q.reshape(B, S, H, nd + rd)
    q_nope, q_rope = q[..., :nd], q[..., nd:]

    kv_a = linear(p["kv_a_proj"], x)
    c_kv, k_rope = kv_a[..., :cfg.kv_lora_rank], kv_a[..., cfg.kv_lora_rank:]
    c_kv = rmsnorm(p["kv_a_norm"], c_kv)
    k_rope = k_rope.reshape(B, S, 1, rd)

    if rope_cs is not None:
        cos_q, sin_q, cos_k, sin_k = rope_cs
        q_rope = apply_rope(q_rope, cos_q, sin_q)
        k_rope = apply_rope(k_rope, cos_k, sin_k)

    new_cache = None
    if cache is not None and block_table is not None:
        cc, rc = cache                       # (P, bs, ...) pools
        bs = cc.shape[1]
        cc = _paged_write(cc, c_kv, block_table, pos, bs)
        rc = _paged_write(rc, k_rope, block_table, pos, bs)
        new_cache = (cc, rc)
        c_kv = _paged_read(cc, block_table)
        k_rope = _paged_read(rc, block_table)
    elif cache is not None:
        cc, rc = cache
        if jnp.ndim(pos) == 0:
            cc = jax.lax.dynamic_update_slice(cc, c_kv.astype(cc.dtype),
                                              (0, pos, 0))
            rc = jax.lax.dynamic_update_slice(rc, k_rope.astype(rc.dtype),
                                              (0, pos, 0, 0))
        else:  # per-row slot positions (continuous batching)
            assert S == 1, "vector pos is a single-token decode path"
            rows = jnp.arange(B)
            cc = cc.at[rows, pos].set(c_kv[:, 0].astype(cc.dtype))
            rc = rc.at[rows, pos].set(k_rope[:, 0].astype(rc.dtype))
        c_kv, k_rope = cc, rc
        new_cache = (cc, rc)

    kv = linear(p["kv_b_proj"], c_kv).reshape(B, c_kv.shape[1], H, nd + vd)
    k_nope, v = kv[..., :nd], kv[..., nd:]
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope, k_nope.shape[:-1] + (rd,))], -1)
    qf = jnp.concatenate([q_nope, q_rope], -1)

    T = k.shape[1]
    offset = pos if pos is not None else 0
    qpos = offset[:, None] + jnp.arange(S) if jnp.ndim(offset) == 1 \
        else offset + jnp.arange(S)
    out = _sdpa(qf, k, v, scale=1.0 / math.sqrt(nd + rd),
                qpos=qpos, kpos=jnp.arange(T), causal=True)
    return linear(p["o_proj"], out.reshape(B, S, H * vd)), new_cache


# --------------------------------------------------------------------- MLPs

def mlp_init(key, d: int, d_ff: int, act: str, dtype=jnp.float32) -> Params:
    ks = jax.random.split(key, 3)
    p = {"up_proj": linear_init(ks[0], d, d_ff, dtype=dtype),
         "down_proj": linear_init(ks[1], d_ff, d, dtype=dtype)}
    if act == "swiglu":
        p["gate_proj"] = linear_init(ks[2], d, d_ff, dtype=dtype)
    return p


def mlp(p: Params, x: jax.Array, act: str) -> jax.Array:
    up = linear(p["up_proj"], x)
    if act == "swiglu":
        h = jax.nn.silu(linear(p["gate_proj"], x)) * up
    elif act == "squared_relu":      # nemotron-4
        h = jnp.square(jax.nn.relu(up))
    elif act == "gelu":
        h = jax.nn.gelu(up)
    else:
        raise ValueError(f"unknown act {act!r}")
    return linear(p["down_proj"], h)
