"""Decoder-LM backbone covering the dense / moe / ssm / hybrid / xlstm / vlm
families, with train, prefill and decode entry points.

One scan-over-layers implementation (stacked params, remat-able body) serves
every family; the block mixer is selected by ``ArchConfig.family``:

  dense   — GQA/MHA attention + MLP (swiglu or squared-relu, optional biases)
  moe     — attention + (MLA for deepseek) + MoE FFN with shared experts
  hybrid  — hymba: parallel attention ‖ mamba heads in every block, sliding-
            window attention except on ``global_layers``
  ssm     — xlstm: mLSTM blocks with sLSTM interleave (own layer loop)
  vlm     — dense backbone consuming [patch embeds ; token embeds]

Caches returned by ``prefill`` and consumed by ``decode_step`` are stacked
(L, ...) pytrees so decode also scans.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers, moe as moe_lib, ssm as ssm_lib, xlstm as xlstm_lib
from repro.models.layers import AttnConfig, MLAConfig

Params = Dict[str, Any]


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                     # dense | moe | hybrid | ssm | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None
    qkv_bias: bool = False
    act: str = "swiglu"
    rope_theta: float = 10000.0
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    # attention variants
    attn_kind: str = "gqa"          # gqa | mla
    mla: Optional[MLAConfig] = None
    sliding_window: Optional[int] = None
    global_layers: Tuple[int, ...] = ()   # hymba: full-attn layer indices
    # moe
    moe: Optional[moe_lib.MoEConfig] = None
    # ssm / hybrid
    ssm: Optional[ssm_lib.SSMConfig] = None
    # xlstm
    xlstm: Optional[xlstm_lib.XLSTMConfig] = None
    # multimodal stub frontend
    frontend: Optional[str] = None  # 'patch' | 'frame'
    frontend_dim: int = 1024
    frontend_len: int = 576
    # encoder-decoder
    encdec: bool = False
    n_enc_layers: int = 0
    # dtype policy
    param_dtype: str = "float32"
    compute_dtype: str = "float32"
    remat: bool = True
    # activation batch sharding pinned at every block boundary (FSDP/ZeRO-3
    # discipline; requires lowering under a mesh context)
    act_batch_axes: Optional[Tuple[str, ...]] = None
    # sequence-sharded attention axis (archs whose head counts do not divide
    # the 'model' axis — see layers.AttnConfig.seq_axis)
    act_seq_axis: Optional[str] = None

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    def attn_cfg(self) -> AttnConfig:
        return AttnConfig(self.d_model, self.n_heads, self.n_kv_heads,
                          self.hd, qkv_bias=self.qkv_bias,
                          rope_theta=self.rope_theta,
                          sliding_window=self.sliding_window,
                          batch_axes=self.act_batch_axes,
                          seq_axis=self.act_seq_axis)

    def sub_quadratic(self) -> bool:
        """Eligible for the long_500k shape (assignment rule)."""
        return self.family in ("ssm",) or (
            self.family == "hybrid" and self.sliding_window is not None)

    def param_count(self) -> int:
        """Total parameters (for 6·N·D roofline bookkeeping)."""
        import numpy as np
        shapes = jax.eval_shape(partial(init, self), jax.random.PRNGKey(0))
        return int(sum(np.prod(l.shape) for l in jax.tree.leaves(shapes)))

    def active_param_count(self) -> int:
        """Active params per token (MoE: shared + top_k experts only)."""
        n = self.param_count()
        if self.moe is None:
            return n
        c = self.moe
        per_expert = 3 * c.d_model * c.d_expert
        inactive = (c.n_experts - c.top_k) * per_expert * self.n_layers
        return n - inactive


# ----------------------------------------------------------- block (init)

def _block_init(cfg: ArchConfig, key) -> Params:
    ks = jax.random.split(key, 8)
    dt = jnp.dtype(cfg.param_dtype)
    p: Params = {"attn_norm": layers.rmsnorm_init(cfg.d_model, dt),
                 "mlp_norm": layers.rmsnorm_init(cfg.d_model, dt)}
    if cfg.family == "ssm":
        raise AssertionError("xlstm family uses its own init path")
    if cfg.attn_kind == "mla":
        p["attn"] = layers.mla_init(ks[0], cfg.mla, dt)
    else:
        p["attn"] = layers.attention_init(ks[0], cfg.attn_cfg(), dt)
    if cfg.family == "hybrid":
        p["ssm"] = ssm_lib.ssm_init(ks[1], cfg.ssm, dt)
        p["mix_scale"] = jnp.ones((2,), dt)   # learned attn/ssm balance
    if cfg.moe is not None:
        p["moe"] = moe_lib.moe_init(ks[2], cfg.moe, dt)
    else:
        p["mlp"] = layers.mlp_init(ks[3], cfg.d_model, cfg.d_ff, cfg.act, dt)
    return p


def init(cfg: ArchConfig, key) -> Params:
    ks = jax.random.split(key, 6)
    dt = jnp.dtype(cfg.param_dtype)
    p: Params = {"embed": layers.embed_init(ks[0], cfg.vocab, cfg.d_model, dt),
                 "final_norm": layers.rmsnorm_init(cfg.d_model, dt)}
    if not cfg.tie_embeddings:
        p["lm_head"] = {"embedding": (jax.random.normal(
            ks[1], (cfg.vocab, cfg.d_model), jnp.float32) * 0.02).astype(dt)}
    if cfg.family == "ssm":       # xlstm
        xc = cfg.xlstm
        n_s = cfg.n_layers // xc.slstm_every
        n_m = cfg.n_layers - n_s
        p["mlstm_blocks"] = jax.vmap(
            lambda k: _xlstm_block_init(cfg, k, "mlstm"))(
                jax.random.split(ks[2], n_m))
        if n_s:
            p["slstm_blocks"] = jax.vmap(
                lambda k: _xlstm_block_init(cfg, k, "slstm"))(
                    jax.random.split(ks[3], n_s))
    else:
        p["blocks"] = jax.vmap(lambda k: _block_init(cfg, k))(
            jax.random.split(ks[2], cfg.n_layers))
    if cfg.frontend is not None:
        p["frontend_proj"] = {
            "fc1": layers.linear_init(ks[4], cfg.frontend_dim,
                                      cfg.d_model, dtype=dt),
            "fc2": layers.linear_init(ks[5], cfg.d_model, cfg.d_model,
                                      dtype=dt)}
    return p


def _xlstm_block_init(cfg: ArchConfig, key, kind: str) -> Params:
    ks = jax.random.split(key, 3)
    dt = jnp.dtype(cfg.param_dtype)
    xc = cfg.xlstm
    d_ff = int(xc.ff_mult * cfg.d_model)
    core = (xlstm_lib.mlstm_init(ks[0], xc, dt) if kind == "mlstm"
            else xlstm_lib.slstm_init(ks[0], xc, dt))
    return {"attn_norm": layers.rmsnorm_init(cfg.d_model, dt),
            "core": core,
            "mlp_norm": layers.rmsnorm_init(cfg.d_model, dt),
            "mlp": layers.mlp_init(ks[1], cfg.d_model, d_ff, "gelu", dt)}


# ---------------------------------------------------------- block (apply)

def _pin_batch(cfg: ArchConfig, x: jax.Array) -> jax.Array:
    if cfg.act_batch_axes is None:
        return x
    from jax.sharding import PartitionSpec as P
    return jax.lax.with_sharding_constraint(
        x, P(cfg.act_batch_axes, *([None] * (x.ndim - 1))))


def _block_apply(cfg: ArchConfig, p: Params, x: jax.Array, *,
                 rope_cs, window_enabled=None, cache=None, ssm_state=None,
                 pos=None, block_table=None):
    """Residual block. Returns (x, new_cache, new_ssm_state)."""
    x = _pin_batch(cfg, x)
    h = layers.rmsnorm(p["attn_norm"], x, cfg.norm_eps)
    new_cache = new_ssm = None
    if cfg.attn_kind == "mla":
        attn_out, new_cache = layers.mla_attention(
            p["attn"], cfg.mla, h, cache=cache, pos=pos, rope_cs=rope_cs,
            block_table=block_table)
    else:
        attn_out, new_cache = layers.attention(
            p["attn"], cfg.attn_cfg(), h, cache=cache, pos=pos,
            rope_cs=rope_cs, window_enabled=window_enabled,
            block_table=block_table)
    if cfg.family == "hybrid":
        ssm_out, new_ssm = ssm_lib.ssm(p["ssm"], cfg.ssm, h, state=ssm_state)
        s = p["mix_scale"].astype(jnp.float32)
        attn_out = (s[0] * attn_out.astype(jnp.float32)
                    + s[1] * ssm_out.astype(jnp.float32)).astype(x.dtype)
    x = x + attn_out
    h = layers.rmsnorm(p["mlp_norm"], x, cfg.norm_eps)
    if cfg.moe is not None:
        x = x + moe_lib.moe(p["moe"], cfg.moe, h)
    else:
        x = x + layers.mlp(p["mlp"], h, cfg.act)
    return x, new_cache, new_ssm


def _rope_angles(hd: int, positions: jax.Array, theta: float):
    """cos/sin computed directly from (possibly traced) positions — no table,
    so 500k-context decode positions never clip."""
    inv = 1.0 / (theta ** (jnp.arange(0, hd, 2, dtype=jnp.float32) / hd))
    ang = positions.astype(jnp.float32)[:, None] * inv[None, :]
    return jnp.cos(ang), jnp.sin(ang)


def _rope_for(cfg: ArchConfig, positions: jax.Array):
    """Self-attention rope: new q and new k share the same positions."""
    hd = cfg.mla.qk_rope_dim if cfg.attn_kind == "mla" else cfg.hd
    cos, sin = _rope_angles(hd, positions, cfg.rope_theta)
    return (cos, sin, cos, sin)


def _window_flags(cfg: ArchConfig) -> Optional[jax.Array]:
    if cfg.family != "hybrid" or cfg.sliding_window is None:
        return None
    flags = jnp.ones((cfg.n_layers,), bool)
    for g in cfg.global_layers:
        flags = flags.at[g].set(False)
    return flags


# ------------------------------------------------------------- entry points

def forward(cfg: ArchConfig, params: Params, tokens: jax.Array, *,
            patches: Optional[jax.Array] = None,
            frames: Optional[jax.Array] = None) -> jax.Array:
    """Training forward: (B, S) tokens -> (B, S, vocab) fp32 logits.
    VLM: patch embeds are projected and prepended (logits cover full seq)."""
    x = layers.embed(params["embed"], tokens).astype(
        jnp.dtype(cfg.compute_dtype))
    n_prefix = 0
    if cfg.frontend is not None:
        emb = patches if patches is not None else frames
        fp = params["frontend_proj"]
        pe = layers.linear(fp["fc2"], jax.nn.gelu(
            layers.linear(fp["fc1"], emb.astype(x.dtype))))
        x = jnp.concatenate([pe, x], axis=1)
        n_prefix = pe.shape[1]
    B, S, _ = x.shape

    if cfg.family == "ssm":
        x = _xlstm_forward(cfg, params, x)
    else:
        positions = jnp.arange(S)
        rope_cs = _rope_for(cfg, positions)
        flags = _window_flags(cfg)

        def body(h, scanned):
            bp = scanned[0]
            wf = scanned[1] if flags is not None else None
            h, _, _ = _block_apply(cfg, bp, h, rope_cs=rope_cs,
                                   window_enabled=wf)
            return h, None
        if cfg.remat:
            body = jax.checkpoint(body)
        xs = (params["blocks"],) + ((flags,) if flags is not None else ())
        x, _ = jax.lax.scan(body, x, xs)

    x = layers.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    head = params.get("lm_head", params["embed"])
    logits = layers.unembed(head, x)
    return logits[:, n_prefix:]


def _xlstm_forward(cfg: ArchConfig, params: Params, x: jax.Array) -> jax.Array:
    xc = cfg.xlstm
    every = xc.slstm_every
    n_s = cfg.n_layers // every
    seg = every - 1                       # mLSTM blocks per segment

    def m_body(h, bp):
        h = _xlstm_block(cfg, bp, h, "mlstm")[0]
        return h, None
    if cfg.remat:
        m_body = jax.checkpoint(m_body)

    mb, sb = params["mlstm_blocks"], params.get("slstm_blocks")
    off = 0
    for s_i in range(max(n_s, 1)):
        take = seg if n_s else cfg.n_layers
        blk = jax.tree.map(lambda a: a[off:off + take], mb)
        x, _ = jax.lax.scan(m_body, x, blk)
        off += take
        if n_s and sb is not None:
            one = jax.tree.map(lambda a: a[s_i], sb)
            x = _xlstm_block(cfg, one, x, "slstm")[0]
    # trailing mLSTM blocks, if any
    rest = (cfg.n_layers - n_s) - off
    if rest > 0:
        blk = jax.tree.map(lambda a: a[off:off + rest], mb)
        x, _ = jax.lax.scan(m_body, x, blk)
    return x


def _xlstm_block(cfg: ArchConfig, p: Params, x: jax.Array, kind: str,
                 state=None):
    h = layers.rmsnorm(p["attn_norm"], x, cfg.norm_eps)
    core = xlstm_lib.mlstm if kind == "mlstm" else xlstm_lib.slstm
    out, new_state = core(p["core"], cfg.xlstm, h, state=state)
    x = x + out
    h = layers.rmsnorm(p["mlp_norm"], x, cfg.norm_eps)
    x = x + layers.mlp(p["mlp"], h, "gelu")
    return x, new_state


# ------------------------------------------------------------ serving paths

def init_cache(cfg: ArchConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16):
    """Stacked (L, ...) cache pytree for decode."""
    L = cfg.n_layers
    if cfg.family == "ssm":
        xc = cfg.xlstm
        n_s = L // xc.slstm_every
        return {
            "mlstm": jax.tree.map(
                lambda z: jnp.broadcast_to(z, (L - n_s,) + z.shape).copy(),
                xlstm_lib.mlstm_init_state(xc, batch)),
            "slstm": jax.tree.map(
                lambda z: jnp.broadcast_to(z, (max(n_s, 1),) + z.shape).copy(),
                xlstm_lib.slstm_init_state(xc, batch)),
        }
    cache: Dict[str, Any] = {}
    eff_len = max_len
    if cfg.sliding_window is not None and not cfg.global_layers:
        eff_len = min(max_len, cfg.sliding_window)
    if cfg.attn_kind == "mla":
        cache["ckv"] = jnp.zeros((L, batch, eff_len, cfg.mla.kv_lora_rank),
                                 dtype)
        cache["krope"] = jnp.zeros((L, batch, eff_len, 1,
                                    cfg.mla.qk_rope_dim), dtype)
    else:
        kvshape = (L, batch, eff_len, cfg.n_kv_heads, cfg.hd)
        cache["k"] = jnp.zeros(kvshape, dtype)
        cache["v"] = jnp.zeros(kvshape, dtype)
    if cfg.family == "hybrid":
        conv, h = ssm_lib.ssm_init_state(cfg.ssm, batch)
        cache["conv"] = jnp.broadcast_to(conv, (L,) + conv.shape).copy()
        cache["ssm_h"] = jnp.broadcast_to(h, (L,) + h.shape).copy()
    return cache


# cache leaves carrying a sequence axis — the ones the paged allocator
# (serve/paged.py) stores block-granular; recurrent leaves (conv/ssm_h,
# xLSTM memories) are O(1) per slot and always stay batch-contiguous
PAGED_CACHE_KEYS = ("k", "v", "ckv", "krope")


def init_paged_cache(cfg: ArchConfig, batch: int, num_blocks: int,
                     block_size: int, dtype=jnp.bfloat16):
    """Decode cache whose attention leaves are block pools: (L, P, bs, ...)
    physical blocks shared by every slot through per-request block tables
    (serve/paged.py), instead of a contiguous (L, B, S_max, ...) row per
    slot.  Block 0 is the reserved null block — free slots' idle writes
    land there and no live table ever maps it, so callers size ``P`` as
    ``pool_blocks + 1``.  Recurrent leaves keep the (L, batch, ...) layout
    of init_cache.  Pure-recurrent families (ssm) have no sequence axis to
    page; callers use init_cache unchanged for them."""
    L = cfg.n_layers
    if cfg.family == "ssm":
        raise ValueError("ssm caches are O(1) recurrent state — nothing to "
                         "page; use init_cache")
    if cfg.sliding_window is not None and not cfg.global_layers:
        # the contiguous tier shrinks these caches to a rolling window
        # buffer (init_cache eff_len); paging a rolling buffer would remap
        # physical blocks every window step — not supported
        raise NotImplementedError(
            "paged cache does not cover rolling sliding-window buffers")
    cache: Dict[str, Any] = {}
    if cfg.attn_kind == "mla":
        cache["ckv"] = jnp.zeros((L, num_blocks, block_size,
                                  cfg.mla.kv_lora_rank), dtype)
        cache["krope"] = jnp.zeros((L, num_blocks, block_size, 1,
                                    cfg.mla.qk_rope_dim), dtype)
    else:
        cache["k"] = jnp.zeros((L, num_blocks, block_size,
                                cfg.n_kv_heads, cfg.hd), dtype)
        cache["v"] = jnp.zeros_like(cache["k"])
    if cfg.family == "hybrid":
        conv, h = ssm_lib.ssm_init_state(cfg.ssm, batch)
        cache["conv"] = jnp.broadcast_to(conv, (L,) + conv.shape).copy()
        cache["ssm_h"] = jnp.broadcast_to(h, (L,) + h.shape).copy()
    return cache


def _layer_cache(cfg, cache, sel):
    if cfg.attn_kind == "mla":
        return (cache["ckv"][sel], cache["krope"][sel])
    return (cache["k"][sel], cache["v"][sel])


def _cache_scan(cfg: ArchConfig, params: Params, x: jax.Array, cache, *,
                pos, positions, remat: bool = False, block_tables=None):
    """Scan the blocks threading the decode cache: shared by prefill
    (pos=0), chunked prefill (scalar pos offset) and decode (scalar pos, or
    a (B,) vector of per-slot positions for continuous batching).
    ``block_tables`` (B, W) switches the attention leaves to the paged
    (L, P, bs, ...) pool layout — one table shared by every layer."""
    rope_cs = _rope_for(cfg, positions)
    flags = _window_flags(cfg)

    def body(h, scanned):
        bp, c_l = scanned[0], scanned[1]
        wf = scanned[2] if flags is not None else None
        ssm_state = (c_l.pop("conv"), c_l.pop("ssm_h")) \
            if cfg.family == "hybrid" else None
        kv = tuple(c_l.values())
        h, new_kv, new_ssm = _block_apply(
            cfg, bp, h, rope_cs=rope_cs, window_enabled=wf,
            cache=kv, ssm_state=ssm_state, pos=pos,
            block_table=block_tables)
        out = dict(zip(c_l.keys(), new_kv))
        if new_ssm is not None:
            out["conv"], out["ssm_h"] = new_ssm
        return h, out
    if remat:
        body = jax.checkpoint(body)
    keys = (["ckv", "krope"] if cfg.attn_kind == "mla" else ["k", "v"])
    cdict = {k: cache[k] for k in keys}
    if cfg.family == "hybrid":
        cdict["conv"], cdict["ssm_h"] = cache["conv"], cache["ssm_h"]
    xs = (params["blocks"], cdict) + \
        ((flags,) if flags is not None else ())
    x, new_cache = jax.lax.scan(body, x, xs)
    return x, {**cache, **new_cache}


def prefill(cfg: ArchConfig, params: Params, tokens: jax.Array,
            max_len: int, *, patches: Optional[jax.Array] = None,
            frames: Optional[jax.Array] = None, cache_dtype=jnp.bfloat16):
    """Process the prompt, returning (last-token logits, filled cache).
    VLM/audio-frontend archs prepend the projected patch/frame embeddings;
    the cache then covers prefix + prompt, and decode positions continue at
    ``prefix_len + S``."""
    x = layers.embed(params["embed"], tokens).astype(
        jnp.dtype(cfg.compute_dtype))
    if cfg.frontend is not None:
        emb = patches if patches is not None else frames
        fp = params["frontend_proj"]
        pe = layers.linear(fp["fc2"], jax.nn.gelu(
            layers.linear(fp["fc1"], emb.astype(x.dtype))))
        x = jnp.concatenate([pe, x], axis=1)
    B, S = x.shape[:2]
    cache = init_cache(cfg, B, max_len, cache_dtype)

    if cfg.family == "ssm":
        x, cache = _xlstm_serve(cfg, params, x, cache)
    else:
        x, cache = _cache_scan(cfg, params, x, cache, pos=0,
                               positions=jnp.arange(S), remat=cfg.remat)

    x = layers.rmsnorm(params["final_norm"], x[:, -1:], cfg.norm_eps)
    head = params.get("lm_head", params["embed"])
    return layers.unembed(head, x)[:, 0], cache


def prefill_chunk(cfg: ArchConfig, params: Params, tokens: jax.Array,
                  cache, pos: jax.Array, block_tables=None):
    """Continue a prefill: write a prompt chunk at positions
    [pos, pos + S) of an existing cache (chunked prefill for prompts too
    long to process in one shot — the long_500k serving path).  Token-only:
    frontend archs prepend their prefix in the first full prefill instead.
    ``block_tables`` (1, W): chunk directly into a paged pool cache through
    the request's block table (serve/paged.py admission path).
    Returns (chunk-final logits, cache)."""
    assert cfg.frontend is None, "chunked prefill is token-only"
    x = layers.embed(params["embed"], tokens).astype(
        jnp.dtype(cfg.compute_dtype))
    S = x.shape[1]

    if cfg.family == "ssm":
        x, cache = _xlstm_serve(cfg, params, x, cache)
    else:
        x, cache = _cache_scan(cfg, params, x, cache, pos=pos,
                               positions=pos + jnp.arange(S),
                               remat=cfg.remat, block_tables=block_tables)

    x = layers.rmsnorm(params["final_norm"], x[:, -1:], cfg.norm_eps)
    head = params.get("lm_head", params["embed"])
    return layers.unembed(head, x)[:, 0], cache


def decode_step(cfg: ArchConfig, params: Params, token: jax.Array,
                cache, pos: jax.Array, block_tables=None):
    """One decode step: (B,) token ids + cache + pos -> (logits, cache).
    pos is a scalar (all rows at the same depth) or a (B,) vector of
    per-row positions (slot-based continuous batching).  ``block_tables``
    (B, W) reads/writes the attention cache through per-slot block tables
    over a paged pool (serve/paged.py); recurrent state is unaffected."""
    x = layers.embed(params["embed"], token[:, None]).astype(
        jnp.dtype(cfg.compute_dtype))

    if cfg.family == "ssm":
        x, cache = _xlstm_serve(cfg, params, x, cache)
    else:
        positions = pos[None] if pos.ndim == 0 else pos[:, None]
        x, cache = _cache_scan(cfg, params, x, cache, pos=pos,
                               positions=positions,
                               block_tables=block_tables)

    x = layers.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    head = params.get("lm_head", params["embed"])
    return layers.unembed(head, x)[:, 0], cache


def _xlstm_serve(cfg: ArchConfig, params: Params, x: jax.Array, cache):
    """xLSTM prefill/decode share the recurrent path (state in, state out)."""
    xc = cfg.xlstm
    every = xc.slstm_every
    n_s = cfg.n_layers // every
    seg = every - 1

    def m_body(carry, scanned):
        h = carry
        bp, st = scanned
        h, new_st = _xlstm_block(cfg, bp, h, "mlstm", state=st)
        return h, new_st

    mb, sb = params["mlstm_blocks"], params.get("slstm_blocks")
    m_state, s_state = cache["mlstm"], cache["slstm"]
    new_m, new_s = [], []
    off = 0
    for s_i in range(max(n_s, 1)):
        take = seg if n_s else cfg.n_layers
        blk = jax.tree.map(lambda a: a[off:off + take], mb)
        st = jax.tree.map(lambda a: a[off:off + take], m_state)
        x, st_out = jax.lax.scan(m_body, x, (blk, st))
        new_m.append(st_out)
        off += take
        if n_s and sb is not None:
            one = jax.tree.map(lambda a: a[s_i], sb)
            st1 = jax.tree.map(lambda a: a[s_i], s_state)
            x, st1_out = _xlstm_block(cfg, one, x, "slstm", state=st1)
            new_s.append(jax.tree.map(lambda a: a[None], st1_out))
    rest = (cfg.n_layers - n_s) - off
    if rest > 0:
        blk = jax.tree.map(lambda a: a[off:off + rest], mb)
        st = jax.tree.map(lambda a: a[off:off + rest], m_state)
        x, st_out = jax.lax.scan(m_body, x, (blk, st))
        new_m.append(st_out)
    cache = {
        "mlstm": jax.tree.map(lambda *xs: jnp.concatenate(xs, 0), *new_m),
        "slstm": (jax.tree.map(lambda *xs: jnp.concatenate(xs, 0), *new_s)
                  if new_s else cache["slstm"]),
    }
    return x, cache
