"""Partitioning rules: parameter, activation and cache shardings.

Megatron-style TP over the 'model' axis (column-parallel in-projections,
row-parallel out-projections — no collective until the block boundary), EP
for MoE experts, vocab-sharded embeddings, optional ZeRO-3 parameter sharding
over the DP axes for the ≥340B configs, and batch/sequence sharding for the
serve caches.  Every rule is divisibility-guarded: a dim that does not divide
the axis extent stays unsharded (e.g. hymba's 25 heads / 32001 vocab).
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

# last-name-component classification
_OUT_SHARDED = {"q_proj", "k_proj", "v_proj", "up_proj", "gate_proj",
                "in_proj", "dt_proj", "w_proj", "r_proj", "fc1",
                "q_a_proj", "q_b_proj", "kv_a_proj", "kv_b_proj"}
_IN_SHARDED = {"o_proj", "down_proj", "out_proj", "fc2"}


def _axis_size(mesh, axes) -> int:
    if isinstance(axes, str):
        axes = (axes,)
    return int(np.prod([mesh.shape[a] for a in axes]))


def _guard(mesh, dim: int, axes):
    """axes if dim divides their extent, else None (stay replicated)."""
    if axes is None:
        return None
    return axes if dim % _axis_size(mesh, axes) == 0 else None


def dp_axes(mesh) -> Tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def param_specs(cfg, params_tree, mesh, *, zero3: bool = False):
    """PartitionSpec pytree matching ``params_tree`` (arrays or structs).

    ZeRO-3 policy (FSDP-in-GSPMD, the MaxText pattern): weight feature dims
    are sharded over the DP axis *and* activations are explicitly pinned to
    batch-sharding at every block boundary (ArchConfig.act_batch_axes).  With
    both constraints the partitioner's cheapest plan is to all-gather each
    layer's weights transiently inside the scan — FSDP semantics.  Without
    the activation pins it instead lowers to accidental 2D-TP (activations
    feature-sharded over 'data', batch replication) — docs/DESIGN.md §9,
    nemotron iterations.
    """
    zaxis = "data" if (zero3 and "data" in mesh.axis_names) else None

    def spec_for(path: str, shape) -> P:
        parts = path.split("/")
        name = parts[-2] if parts[-1] in ("w", "b") else parts[-1]
        rank = len(shape)

        if parts[-1] == "b":  # bias (..., out)
            return P(*([None] * (rank - 1)),
                     _guard(mesh, shape[-1], "model"))
        if name == "embedding":
            return P(_guard(mesh, shape[-2], "model"), None) if rank == 2 \
                else P(*([None] * (rank - 2)),
                       _guard(mesh, shape[-2], "model"), None)
        if "experts" in parts:  # (L, E, din, dout): EP over 'model'
            # ZeRO-3 'data' goes on the d_ff dim in Megatron pairing —
            # out-dim for gate/up, in-dim for down — so the expert FFN incurs
            # ONE activation all-reduce instead of one per GEMM (contracting
            # on a sharded din); docs/DESIGN.md §9, kimi iteration.
            if name in _IN_SHARDED:      # down_proj (L, E, dff, d)
                return P(*([None] * (rank - 3)),
                         _guard(mesh, shape[-3], "model"),
                         _guard(mesh, shape[-2], zaxis), None)
            return P(*([None] * (rank - 3)),   # gate/up (L, E, d, dff)
                     _guard(mesh, shape[-3], "model"),
                     None, _guard(mesh, shape[-1], zaxis))
        if name == "router_w":
            return P(*([None] * (rank - 1)),
                     _guard(mesh, shape[-1], "model"))
        if name == "conv_w":  # (L, K, di)
            return P(*([None] * (rank - 1)),
                     _guard(mesh, shape[-1], "model"))
        if name == "a_log":   # (L, di, ds)
            return P(*([None] * (rank - 2)),
                     _guard(mesh, shape[-2], "model"), None)
        if name in ("dt_bias", "d_skip", "gate_bias", "if_gate_bias"):
            return P(*([None] * (rank - 1)),
                     _guard(mesh, shape[-1], "model"))
        if name in _OUT_SHARDED and parts[-1] == "w":
            return P(*([None] * (rank - 2)),
                     _guard(mesh, shape[-2], zaxis),
                     _guard(mesh, shape[-1], "model"))
        if name in _IN_SHARDED and parts[-1] == "w":
            return P(*([None] * (rank - 2)),
                     _guard(mesh, shape[-2], "model"),
                     _guard(mesh, shape[-1], zaxis))
        return P(*([None] * rank))  # norms, scalars, small gates

    flat = jax.tree_util.tree_flatten_with_path(params_tree)[0]
    treedef = jax.tree_util.tree_structure(params_tree)
    specs = []
    for kp, leaf in flat:
        path = "/".join(_key_str(k) for k in kp)
        specs.append(spec_for(path, leaf.shape))
    return jax.tree_util.tree_unflatten(treedef, specs)


def _key_str(k) -> str:
    for attr in ("key", "idx", "name"):
        if hasattr(k, attr):
            return str(getattr(k, attr))
    return str(k)


def batch_spec(mesh, batch: int) -> P:
    axes = dp_axes(mesh)
    if axes and batch % _axis_size(mesh, axes) == 0:
        return P(axes)
    if "data" in mesh.axis_names and batch % mesh.shape["data"] == 0:
        return P(("data",))
    return P(None)


def input_shardings(cfg, specs_dict, mesh):
    """NamedShardings for the input_specs() dict of a cell (batch-sharded)."""
    out = {}
    for name, sd in specs_dict.items():
        if sd.ndim == 0:
            out[name] = NamedSharding(mesh, P())
        else:
            bs = batch_spec(mesh, sd.shape[0])
            out[name] = NamedSharding(
                mesh, P(*(bs + P(*([None] * (sd.ndim - 1))))))
    return out


def cache_specs(cfg, cache_tree, mesh):
    """Decode-cache shardings: batch over DP; kv-heads over 'model' when
    divisible, otherwise sequence sharding over 'model' (the fallback that
    also serves the b=1 long-context cells).  Leaf ranks:
      (L,B,S,KV,hd) attention KV · (L,B,S,r) MLA latent ·
      (L,B,S,1,rd) MLA rope key · (L,B,K,di) ssm conv · (L,B,di,ds) ssm h ·
      (n,B,H,hd,hd)/(n,B,H,hd)/(n,B,H) mLSTM · (n,B,d) sLSTM."""
    def spec_for(path: str, shape) -> P:
        rank = len(shape)
        if rank < 3:
            return P(*([None] * rank))
        dims = [None] * rank
        if shape[1] > 1:
            dims[1] = _guard(mesh, shape[1], dp_axes(mesh))
        leafname = path.split("/")[-1]
        if rank >= 5:                       # (L,B,S,KV,hd) or mLSTM C
            dims[3] = _guard(mesh, shape[3], "model")
            if dims[3] is None:
                dims[2] = _guard(mesh, shape[2], "model")
        elif rank == 4:
            if "conv" in leafname:          # (L,B,K,di): shard channels
                dims[3] = _guard(mesh, shape[3], "model")
            else:                           # (L,B,S,r) latent / (L,B,di,ds)
                dims[2] = _guard(mesh, shape[2], "model")
        else:                               # (L,B,X)
            dims[2] = _guard(mesh, shape[2], "model")
        return P(*dims)

    flat = jax.tree_util.tree_flatten_with_path(cache_tree)[0]
    treedef = jax.tree_util.tree_structure(cache_tree)
    specs = []
    for kp, leaf in flat:
        path = "/".join(_key_str(k) for k in kp)
        specs.append(spec_for(path, leaf.shape))
    return jax.tree_util.tree_unflatten(treedef, specs)
