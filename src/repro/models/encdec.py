"""Encoder-decoder backbone (seamless-m4t style, audio frontend stubbed).

Encoder consumes precomputed frame embeddings (the modality frontend is a
stub per the assignment: ``input_specs()`` provides (B, S_enc, frontend_dim)
arrays), projects them to d_model and runs non-causal attention blocks.
Decoder blocks are self-attention (causal, cached) + cross-attention over the
encoder output (KV cached once at prefill) + MLP.

Entry points mirror transformer.py: forward / prefill / decode_step.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers
from repro.models.layers import AttnConfig
from repro.models.transformer import ArchConfig, _rope_for

Params = Dict[str, Any]


def _enc_attn_cfg(cfg: ArchConfig) -> AttnConfig:
    return AttnConfig(cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd,
                      causal=False, rope_theta=cfg.rope_theta)


def _enc_block_init(cfg: ArchConfig, key) -> Params:
    ks = jax.random.split(key, 2)
    dt = jnp.dtype(cfg.param_dtype)
    return {
        "attn_norm": layers.rmsnorm_init(cfg.d_model, dt),
        "attn": layers.attention_init(ks[0], _enc_attn_cfg(cfg), dt),
        "mlp_norm": layers.rmsnorm_init(cfg.d_model, dt),
        "mlp": layers.mlp_init(ks[1], cfg.d_model, cfg.d_ff, cfg.act, dt),
    }


def _dec_block_init(cfg: ArchConfig, key) -> Params:
    ks = jax.random.split(key, 3)
    dt = jnp.dtype(cfg.param_dtype)
    return {
        "attn_norm": layers.rmsnorm_init(cfg.d_model, dt),
        "attn": layers.attention_init(ks[0], cfg.attn_cfg(), dt),
        "cross_norm": layers.rmsnorm_init(cfg.d_model, dt),
        "cross_attn": layers.attention_init(ks[1], _enc_attn_cfg(cfg), dt),
        "mlp_norm": layers.rmsnorm_init(cfg.d_model, dt),
        "mlp": layers.mlp_init(ks[2], cfg.d_model, cfg.d_ff, cfg.act, dt),
    }


def init(cfg: ArchConfig, key) -> Params:
    ks = jax.random.split(key, 6)
    dt = jnp.dtype(cfg.param_dtype)
    n_enc = cfg.n_enc_layers or cfg.n_layers
    return {
        "frontend_proj": {
            "fc1": layers.linear_init(ks[0], cfg.frontend_dim, cfg.d_model,
                                      dtype=dt),
            "fc2": layers.linear_init(ks[1], cfg.d_model, cfg.d_model,
                                      dtype=dt)},
        "embed": layers.embed_init(ks[2], cfg.vocab, cfg.d_model, dt),
        "enc_blocks": jax.vmap(lambda k: _enc_block_init(cfg, k))(
            jax.random.split(ks[3], n_enc)),
        "dec_blocks": jax.vmap(lambda k: _dec_block_init(cfg, k))(
            jax.random.split(ks[4], cfg.n_layers)),
        "enc_norm": layers.rmsnorm_init(cfg.d_model, dt),
        "final_norm": layers.rmsnorm_init(cfg.d_model, dt),
        "lm_head": {"embedding": (jax.random.normal(
            ks[5], (cfg.vocab, cfg.d_model), jnp.float32) * 0.02).astype(dt)},
    }


def encode(cfg: ArchConfig, params: Params, frames: jax.Array) -> jax.Array:
    """frames: (B, S_enc, frontend_dim) -> (B, S_enc, d_model)."""
    fp = params["frontend_proj"]
    x = layers.linear(fp["fc2"], jax.nn.gelu(
        layers.linear(fp["fc1"], frames.astype(jnp.dtype(cfg.compute_dtype)))))
    S = x.shape[1]
    rope_cs = _rope_for(cfg, jnp.arange(S))
    acfg = _enc_attn_cfg(cfg)

    def body(h, bp):
        from repro.models.transformer import _pin_batch
        h = _pin_batch(cfg, h)
        a = layers.rmsnorm(bp["attn_norm"], h, cfg.norm_eps)
        out, _ = layers.attention(bp["attn"], acfg, a, rope_cs=rope_cs)
        h = h + out
        m = layers.rmsnorm(bp["mlp_norm"], h, cfg.norm_eps)
        return h + layers.mlp(bp["mlp"], m, cfg.act), None
    if cfg.remat:
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, params["enc_blocks"])
    return layers.rmsnorm(params["enc_norm"], x, cfg.norm_eps)


def _dec_block(cfg: ArchConfig, bp: Params, h: jax.Array, enc_out, *,
               rope_cs, self_cache=None, cross_cache=None, pos=None):
    from repro.models.transformer import _pin_batch
    h = _pin_batch(cfg, h)
    a = layers.rmsnorm(bp["attn_norm"], h, cfg.norm_eps)
    out, new_self = layers.attention(bp["attn"], cfg.attn_cfg(), a,
                                     cache=self_cache, pos=pos,
                                     rope_cs=rope_cs)
    h = h + out
    c = layers.rmsnorm(bp["cross_norm"], h, cfg.norm_eps)
    out, _ = layers.attention(bp["cross_attn"], _enc_attn_cfg(cfg), c,
                              xk=enc_out, cache=cross_cache,
                              static_cache=cross_cache is not None)
    h = h + out
    m = layers.rmsnorm(bp["mlp_norm"], h, cfg.norm_eps)
    return h + layers.mlp(bp["mlp"], m, cfg.act), new_self


def forward(cfg: ArchConfig, params: Params, tokens: jax.Array, *,
            frames: jax.Array, patches=None) -> jax.Array:
    """Training: frames (B,S_enc,F) + decoder tokens (B,S_dec) -> logits."""
    enc_out = encode(cfg, params, frames)
    x = layers.embed(params["embed"], tokens).astype(
        jnp.dtype(cfg.compute_dtype))
    rope_cs = _rope_for(cfg, jnp.arange(x.shape[1]))

    def body(h, bp):
        h, _ = _dec_block(cfg, bp, h, enc_out, rope_cs=rope_cs)
        return h, None
    if cfg.remat:
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, params["dec_blocks"])
    x = layers.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return layers.unembed(params["lm_head"], x)


def init_cache(cfg: ArchConfig, batch: int, max_len: int, enc_len: int,
               dtype=jnp.bfloat16):
    L = cfg.n_layers
    kvshape = (L, batch, max_len, cfg.n_kv_heads, cfg.hd)
    cross = (L, batch, enc_len, cfg.n_kv_heads, cfg.hd)
    return {"k": jnp.zeros(kvshape, dtype), "v": jnp.zeros(kvshape, dtype),
            "cross_k": jnp.zeros(cross, dtype),
            "cross_v": jnp.zeros(cross, dtype)}


def prefill(cfg: ArchConfig, params: Params, tokens: jax.Array, *,
            frames: jax.Array, max_len: int, cache_dtype=jnp.bfloat16):
    """Encode + consume the decoder prompt; returns (logits, cache)."""
    B, S = tokens.shape
    enc_out = encode(cfg, params, frames)
    cache = init_cache(cfg, B, max_len, enc_out.shape[1], cache_dtype)

    # precompute per-layer cross KV once (paper-standard enc-dec serving)
    def cross_kv(bp):
        acfg = _enc_attn_cfg(cfg)
        k = layers.linear(bp["cross_attn"]["k_proj"], enc_out)
        v = layers.linear(bp["cross_attn"]["v_proj"], enc_out)
        KV, hd = acfg.n_kv_heads, acfg.head_dim
        return (k.reshape(B, -1, KV, hd).astype(cache_dtype),
                v.reshape(B, -1, KV, hd).astype(cache_dtype))
    ck, cv = jax.vmap(cross_kv)(params["dec_blocks"])
    cache["cross_k"], cache["cross_v"] = ck, cv

    x = layers.embed(params["embed"], tokens).astype(
        jnp.dtype(cfg.compute_dtype))
    rope_cs = _rope_for(cfg, jnp.arange(S))

    def body(h, scanned):
        bp, kc, vc, ckc, cvc = scanned
        h, new_self = _dec_block(cfg, bp, h, None, rope_cs=rope_cs,
                                 self_cache=(kc, vc),
                                 cross_cache=(ckc, cvc), pos=0)
        return h, new_self
    if cfg.remat:
        body = jax.checkpoint(body)
    x, (nk, nv) = jax.lax.scan(
        body, x, (params["dec_blocks"], cache["k"], cache["v"],
                  cache["cross_k"], cache["cross_v"]))
    cache["k"], cache["v"] = nk, nv
    x = layers.rmsnorm(params["final_norm"], x[:, -1:], cfg.norm_eps)
    return layers.unembed(params["lm_head"], x)[:, 0], cache


def decode_step(cfg: ArchConfig, params: Params, token: jax.Array, cache,
                pos: jax.Array):
    """pos: scalar shared position, or (B,) per-slot positions (continuous
    batching — the self-attn cache rows advance independently)."""
    x = layers.embed(params["embed"], token[:, None]).astype(
        jnp.dtype(cfg.compute_dtype))
    positions = pos[None] if pos.ndim == 0 else pos[:, None]
    rope_cs = _rope_for(cfg, positions)

    def body(h, scanned):
        bp, kc, vc, ckc, cvc = scanned
        h, new_self = _dec_block(cfg, bp, h, None, rope_cs=rope_cs,
                                 self_cache=(kc, vc),
                                 cross_cache=(ckc, cvc), pos=pos)
        return h, new_self
    x, (nk, nv) = jax.lax.scan(
        body, x, (params["dec_blocks"], cache["k"], cache["v"],
                  cache["cross_k"], cache["cross_v"]))
    cache = {**cache, "k": nk, "v": nv}
    x = layers.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return layers.unembed(params["lm_head"], x)[:, 0], cache
