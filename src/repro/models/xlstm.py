"""xLSTM blocks (Beck et al., arXiv:2405.04517): mLSTM and sLSTM.

mLSTM — matrix-memory LSTM: per head a (hd × hd) memory C updated as
    C_t = f_t·C_{t-1} + i_t·(v_t k_tᵀ),  n_t = f_t·n_{t-1} + i_t·k_t
    y_t = (C_t q_t) / max(|n_tᵀ q_t|, 1)
with exponential input gates stabilized by a running max m_t.  The update is
associative in (log-gate, C, n), so training runs as an associative scan over
time — O(S) work, which is what qualifies xlstm for the long_500k shape.

sLSTM — scalar-memory LSTM with exponential gating, per-head recurrence that
is inherently sequential (lax.scan over time), interleaved every
``slstm_every`` blocks as in the paper's [7:1]-style layouts.

Muon-eligible leaves: q/k/v/o projections, up/down FFN, r/w sLSTM matrices.
Gate biases / skip scalars stay on AdamW via the name rules.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers


@dataclasses.dataclass(frozen=True)
class XLSTMConfig:
    d_model: int
    n_heads: int
    slstm_every: int = 8        # every k-th block is an sLSTM block
    ff_mult: float = 2.0

    @property
    def head_dim(self) -> int:
        return self.d_model // self.n_heads


# ------------------------------------------------------------------- mLSTM

def mlstm_init(key, cfg: XLSTMConfig, dtype=jnp.float32):
    ks = jax.random.split(key, 7)
    d = cfg.d_model
    return {
        "q_proj": layers.linear_init(ks[0], d, d, dtype=dtype),
        "k_proj": layers.linear_init(ks[1], d, d, dtype=dtype),
        "v_proj": layers.linear_init(ks[2], d, d, dtype=dtype),
        "o_proj": layers.linear_init(ks[3], d, d, dtype=dtype),
        "if_gate_bias": jnp.zeros((2 * cfg.n_heads,), dtype),
        "if_gate_w": (jax.random.normal(ks[4], (d, 2 * cfg.n_heads),
                                        jnp.float32) * 0.02).astype(dtype),
    }


def mlstm(p, cfg: XLSTMConfig, x: jax.Array, *,
          state: Optional[Tuple] = None):
    """x: (B,S,d). state=(C, n, m) for decode carry. Returns (y, state)."""
    B, S, d = x.shape
    H, hd = cfg.n_heads, cfg.head_dim
    q = layers.linear(p["q_proj"], x).reshape(B, S, H, hd)
    k = layers.linear(p["k_proj"], x).reshape(B, S, H, hd) / math.sqrt(hd)
    v = layers.linear(p["v_proj"], x).reshape(B, S, H, hd)
    gates = (layers.dot(x, p["if_gate_w"])
             + p["if_gate_bias"].astype(x.dtype)).astype(jnp.float32)
    ig, fg = jnp.split(gates.reshape(B, S, 2, H), 2, axis=2)
    ig = ig[:, :, 0]                                  # (B,S,H) log-space input
    fg = jax.nn.log_sigmoid(fg[:, :, 0])              # (B,S,H) log forget

    # stabilizer: m_t = max(f_t + m_{t-1}, i_t); scan is associative in
    # (cumulative log f, running max) — use cumsum trick:
    cum_f = jnp.cumsum(fg, axis=1)                    # (B,S,H)
    # a_t = exp(i_t - m_t), with m_t = max over j<=t of (i_j + cumf_t - cumf_j)
    shifted = ig - cum_f                              # i_j - cumf_j
    run_max = jax.lax.associative_scan(jnp.maximum, shifted, axis=1)
    m = run_max + cum_f                               # (B,S,H)
    a = jnp.exp(shifted - run_max)                    # normalized input gate

    kv = jnp.einsum("bshd,bshe->bshde", v.astype(jnp.float32),
                    k.astype(jnp.float32))
    # Stabilized coefficients: C_t = Σ_j exp(shifted_j − run_max_t) v_j k_jᵀ
    # (the cumf terms cancel inside the max-stabilized form), so the scan
    # decay between steps is exp(run_max_{t−1} − run_max_t) and each element
    # enters with weight a_t = exp(shifted_t − run_max_t).
    def combine(c1, c2):
        f1, kv1, n1 = c1
        f2, kv2, n2 = c2
        return f1 * f2, f2 * kv1 + kv2, f2 * n1 + n2
    decay = jnp.exp(jnp.concatenate(
        [run_max[:, :1], run_max[:, :-1]], 1) - run_max)
    a_ = a[..., None, None]
    _, C, n5 = jax.lax.associative_scan(
        combine,
        (decay[..., None, None], a_ * kv,
         (a[..., None] * k.astype(jnp.float32))[..., None]),  # rank-5 n
        axis=1)
    n = n5[..., 0]

    if state is not None:
        # decode path (S small): fold carried state sequentially
        C0, n0, m0 = state
        ms = jnp.maximum(m0[:, None] + cum_f, m)
        scale_old = jnp.exp(m0[:, None] + cum_f - ms)
        scale_new = jnp.exp(m - ms)
        C = scale_new[..., None, None] * C + \
            scale_old[..., None, None] * C0[:, None]
        n = scale_new[..., None] * n + scale_old[..., None] * n0[:, None]
        m = ms

    qf = q.astype(jnp.float32)
    num = jnp.einsum("bshde,bshe->bshd", C, qf)
    den = jnp.abs(jnp.einsum("bshe,bshe->bsh", n, qf))
    y = num / jnp.maximum(den, 1.0)[..., None]
    out = layers.linear(p["o_proj"], y.astype(x.dtype).reshape(B, S, d))
    new_state = (C[:, -1], n[:, -1], m[:, -1])
    return out, new_state


def mlstm_init_state(cfg: XLSTMConfig, batch: int):
    H, hd = cfg.n_heads, cfg.head_dim
    return (jnp.zeros((batch, H, hd, hd), jnp.float32),
            jnp.zeros((batch, H, hd), jnp.float32),
            jnp.full((batch, H), -1e30, jnp.float32))


# ------------------------------------------------------------------- sLSTM

def slstm_init(key, cfg: XLSTMConfig, dtype=jnp.float32):
    ks = jax.random.split(key, 3)
    d = cfg.d_model
    return {
        "w_proj": layers.linear_init(ks[0], d, 4 * d, dtype=dtype),
        "r_proj": layers.linear_init(ks[1], d, 4 * d, dtype=dtype),
        "gate_bias": jnp.zeros((4 * d,), dtype),
        "o_proj": layers.linear_init(ks[2], d, d, dtype=dtype),
    }


def slstm(p, cfg: XLSTMConfig, x: jax.Array, *,
          state: Optional[Tuple] = None):
    """Sequential scalar-memory LSTM with exponential gating.
    x: (B,S,d); state=(c,n,h,m). Returns (y, state)."""
    B, S, d = x.shape
    wx = layers.linear(p["w_proj"], x) + p["gate_bias"].astype(x.dtype)
    if state is None:
        c0 = jnp.zeros((B, d), jnp.float32)
        n0 = jnp.zeros((B, d), jnp.float32)
        h0 = jnp.zeros((B, d), jnp.float32)
        m0 = jnp.full((B, d), -1e30, jnp.float32)
    else:
        c0, n0, h0, m0 = state
    rw = p["r_proj"]

    def step(carry, wx_t):
        c, n, h, m = carry
        # carry stays fp32 (the cache/init dtype) regardless of the bf16
        # compute dtype — scan requires carry-in == carry-out types
        pre = (wx_t.astype(jnp.float32)
               + layers.linear(rw, h.astype(x.dtype)).astype(jnp.float32))
        zi, ii, fi, oi = jnp.split(pre, 4, axis=-1)
        z = jnp.tanh(zi)
        lf = jax.nn.log_sigmoid(fi)
        mn = jnp.maximum(lf + m, ii)
        i_ = jnp.exp(ii - mn)
        f_ = jnp.exp(lf + m - mn)
        c = f_ * c + i_ * z
        n = f_ * n + i_
        h_new = jax.nn.sigmoid(oi) * c / jnp.maximum(n, 1.0)
        return (c, n, h_new, mn), h_new.astype(x.dtype)

    (c, n, h, m), ys = jax.lax.scan(step, (c0, n0, h0, m0),
                                    jnp.swapaxes(wx, 0, 1))
    y = jnp.swapaxes(ys, 0, 1)
    out = layers.linear(p["o_proj"], y)
    return out, (c, n, h, m)


def slstm_init_state(cfg: XLSTMConfig, batch: int, dtype=jnp.float32):
    d = cfg.d_model
    return (jnp.zeros((batch, d), jnp.float32),
            jnp.zeros((batch, d), jnp.float32),
            jnp.zeros((batch, d), dtype),
            jnp.full((batch, d), -1e30, jnp.float32))
