"""Training state container + dtype policies."""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class TrainState(NamedTuple):
    step: jax.Array          # int32 scalar
    params: Any              # model params (master dtype)
    opt_state: Any           # MuonState
    loss_ema: jax.Array      # running loss for logging


@dataclass(frozen=True)
class DtypePolicy:
    """param = master storage; compute = activations/matmul inputs."""
    param: str = "float32"
    compute: str = "float32"

    def cast_compute(self, tree):
        c = jnp.dtype(self.compute)
        return jax.tree.map(
            lambda x: x.astype(c) if jnp.issubdtype(x.dtype, jnp.floating)
            else x, tree)
