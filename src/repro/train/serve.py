"""Serving-step factories: prefill and decode with sharded KV caches.

``make_prefill`` / ``make_decode`` produce the jit-able callables the
dry-run lowers for the prefill_32k / decode_32k / long_500k shapes.  Cache
shardings come from models/sharding.cache_specs (batch over DP, kv-heads
over 'model', sequence over 'model' as the fallback for b=1 long-context).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding

from repro.models import model_fns, sharding as shard_rules

# PartitionSpec's import home has moved across JAX releases; resolve the
# canonical class once, here (same shim pattern as core/owner_comms.py's
# shard_map and kernels/__init__.py's CompilerParams).
PartitionSpec = getattr(jax.sharding, "PartitionSpec", None)
if PartitionSpec is None:  # pragma: no cover — depends on the installed JAX
    from jax.interpreters.pxla import PartitionSpec


def prefill_fn(cfg, params, tokens, max_len: int, *,
               cache_dtype=jnp.bfloat16, **kwargs):
    """Functional prefill used by examples and the dry-run step builders."""
    m = model_fns(cfg)
    if cfg.encdec:
        return m.prefill(cfg, params, tokens, frames=kwargs["frames"],
                         max_len=max_len, cache_dtype=cache_dtype)
    if cfg.family == "ssm":
        return m.prefill(cfg, params, tokens, max_len)
    return m.prefill(cfg, params, tokens, max_len,
                     cache_dtype=cache_dtype, **kwargs)


def prefill_chunk_fn(cfg, params, tokens, cache, pos, block_tables=None):
    """Chunked-prefill continuation: write a prompt chunk at [pos, pos+S)
    of an existing cache (serve tier, long-prompt path; token-only).
    ``block_tables`` (1, W) writes the chunk straight into a paged pool
    cache through the request's block table (serve/paged.py)."""
    if cfg.encdec:
        raise NotImplementedError(
            "chunked prefill covers decoder-only families; enc-dec prompts "
            "prefill in one shot")
    m = model_fns(cfg)
    if block_tables is None:
        return m.prefill_chunk(cfg, params, tokens, cache, pos)
    return m.prefill_chunk(cfg, params, tokens, cache, pos,
                           block_tables=block_tables)


def decode_fn(cfg, params, token, cache, pos, block_tables=None):
    """One decode step; ``pos`` is a scalar, or a (B,) vector of per-slot
    positions when driven by the continuous-batching scheduler.  With
    ``block_tables`` (B, W) the attention cache is the paged pool layout
    (serve/paged.py) instead of contiguous per-slot rows."""
    m = model_fns(cfg)
    if block_tables is None:
        return m.decode_step(cfg, params, token, cache, pos)
    return m.decode_step(cfg, params, token, cache, pos,
                         block_tables=block_tables)


def make_cache_shapes(cfg, batch: int, max_len: int,
                      cache_dtype=jnp.bfloat16):
    """ShapeDtypeStructs of the decode cache (no allocation) for dry-runs."""
    m = model_fns(cfg)
    if cfg.encdec:
        fn = lambda: m.init_cache(cfg, batch, max_len, max_len, cache_dtype)
    else:
        fn = lambda: m.init_cache(cfg, batch, max_len, cache_dtype)
    return jax.eval_shape(fn)


def cache_shardings(cfg, cache_shapes, mesh):
    specs = shard_rules.cache_specs(cfg, cache_shapes, mesh)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, PartitionSpec))
