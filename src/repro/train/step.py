"""Train-step factory: loss, grad accumulation, remat, shardings, donation.

``make_train_step`` builds the jit'd DMuon training step (Alg. 1 end-to-end):
forward/backward on the DP/TP-sharded model, then the optimizer transform —
owner-centric DMuon, gather-then-compute Muon-AG, or AdamW, selected by the
MuonConfig the caller provides.  The optimizer's owner transposes and the
publish all-gathers sit in the same XLA program as fwd/bwd, so the scheduler
overlaps them with step compute (docs/DESIGN.md §2).

Microbatching: ``accum_steps`` splits the global batch on the leading axis
and accumulates grads with a lax.scan (memory ∝ one microbatch).

Pipelines (``pipeline=`` / ``MuonConfig.pipeline``; docs/DESIGN.md §6):

* ``"fused"``    — the optimizer runs as one post-backward phase (default).
* ``"bucketed"`` — per-Gram-bucket stage_in/compute/publish schedule
  (core/pipeline.py).  With ``accum_steps > 1`` the matrix gradients are
  additionally packed to the owner layout INSIDE the microbatch scan and
  accumulated there, so each microbatch's staged all-to-alls overlap the next
  microbatch's forward/backward instead of forming a post-backward barrier.
  Bit-exact with ``"fused"`` on every registry variant
  (tests/test_pipeline.py).
"""

from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.api import Muon
from repro.models import model_fns, sharding as shard_rules
from repro.train.train_state import TrainState


def softmax_xent(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Cross entropy in a vocab-sharding-friendly form.

    ``take_along_axis`` over a vocab-sharded logits tensor forces the SPMD
    partitioner to replicate the batch dim (a full-logits all-reduce per
    microbatch — see docs/DESIGN.md §9).  The where/sum form reduces over
    the sharded vocab axis locally and only all-reduces (B, S) scalars.
    """
    lg = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(lg, axis=-1)
    vocab_iota = jax.lax.broadcasted_iota(labels.dtype, lg.shape,
                                          lg.ndim - 1)
    gold = jnp.sum(jnp.where(vocab_iota == labels[..., None], lg, 0.0),
                   axis=-1)
    return jnp.mean(lse - gold)


def make_loss_fn(cfg, mesh=None):
    m = model_fns(cfg)

    def loss_fn(params, batch):
        kwargs = {k: batch[k] for k in ("patches", "frames") if k in batch}
        logits = m.forward(cfg, params, batch["tokens"], **kwargs)
        if mesh is not None:
            # keep the (B, S, V) logits sharded: vocab over 'model' when it
            # divides, else sequence-parallel loss (odd vocabs like hymba's
            # 32001); batch over the DP axes throughout.
            dp = shard_rules.dp_axes(mesh)
            ms = mesh.shape["model"]
            if logits.shape[-1] % ms == 0:
                spec = P(dp, None, "model")
            elif logits.shape[1] % ms == 0:
                spec = P(dp, "model", None)
            else:
                spec = P(dp, None, None)
            logits = jax.lax.with_sharding_constraint(
                logits, NamedSharding(mesh, spec))
        return softmax_xent(logits, batch["labels"])
    return loss_fn


def make_train_step(cfg, opt: Muon, mesh=None, *, accum_steps: int = 1,
                    donate: bool = True, grad_specs=None,
                    accum_dtype=jnp.float32, pipeline: Optional[str] = None,
                    prestage: Optional[bool] = None):
    """Returns ``step(state, batch) -> state`` (jit'd when mesh is given).

    ``grad_specs``: optional PartitionSpec pytree matching params — pins the
    gradient accumulator to the parameter shardings (otherwise the SPMD
    partitioner may replicate the fp32 accumulator, which at 671B+ scale is
    the largest buffer in the program).

    ``pipeline``: overrides ``opt.config.pipeline`` ('fused' | 'bucketed');
    see the module docstring and docs/DESIGN.md §6.

    ``prestage``: force the accumulation-overlapped staging on/off (None =
    auto).  Auto enables it for bucketed owner mode with accumulation on a
    multi-device mesh: per-microbatch staging only pays when the owner
    all-to-alls are real transfers that can ride under the next
    microbatch's fwd/bwd — on one device it is N packs instead of one.
    Forcing it on is bit-exact either way (tests/test_pipeline.py).
    """
    if pipeline is not None and pipeline != opt.config.pipeline:
        opt = opt.replace(pipeline=pipeline)
    # The accumulation-overlapped schedule: stage matrix grads to owners
    # per microbatch inside the scan.  Compression accumulates its error
    # feedback on the SUMMED training-layout gradient, so it keeps the
    # unstaged path.
    multi_device = mesh is not None and mesh.devices.size > 1
    if prestage is None:
        prestage = multi_device
    prestage = (prestage and opt.config.pipeline == "bucketed"
                and accum_steps > 1
                and opt.effective_mode == "owner"
                and not opt.config.compress_grads)
    loss_fn = make_loss_fn(cfg, mesh)

    def _pin(tree):
        if mesh is None or grad_specs is None:
            return tree
        return jax.tree.map(
            lambda x, s: jax.lax.with_sharding_constraint(
                x, NamedSharding(mesh, s)), tree, grad_specs,
            is_leaf=lambda x: x is None)

    def split(x):
        out = x.reshape((accum_steps, -1) + x.shape[1:])
        if mesh is not None:
            # keep each microbatch DP-sharded: the reshape otherwise lets
            # the partitioner replicate the batch axis inside the scan
            dp = shard_rules.dp_axes(mesh)
            from repro.models.sharding import _axis_size
            if out.shape[1] % _axis_size(mesh, dp) == 0:
                out = jax.lax.with_sharding_constraint(
                    out, NamedSharding(mesh, P(
                        None, dp, *([None] * (out.ndim - 2)))))
        return out

    def compute_grads(params, batch):
        if accum_steps == 1:
            return jax.value_and_grad(loss_fn)(params, batch)

        def micro(carry, mb):
            loss_acc, grad_acc = carry
            loss, grads = jax.value_and_grad(loss_fn)(params, mb)
            return (loss_acc + loss,
                    _pin(jax.tree.map(lambda a, g: a + g.astype(a.dtype),
                                      grad_acc, grads))), None

        micro_batches = jax.tree.map(split, batch)
        zero = _pin(jax.tree.map(
            lambda p: jnp.zeros(p.shape, accum_dtype), params))
        (loss, grads), _ = jax.lax.scan(
            micro, (jnp.zeros((), jnp.float32), zero), micro_batches)
        inv = 1.0 / accum_steps
        return loss * inv, jax.tree.map(lambda g: g * inv, grads)

    if prestage:
        from repro.core.muon import _matrix_and_rest
        from repro.core.pipeline import BucketPipeline
        pipe = BucketPipeline(opt.plan, opt.config, mesh, opt.variant)

        rest_specs = None
        if mesh is not None and grad_specs is not None:
            from repro.core.dedication import _key_str
            rest_specs = {}
            for kp, spec in jax.tree_util.tree_leaves_with_path(
                    grad_specs, is_leaf=lambda x: x is None
                    or isinstance(x, P)):
                rest_specs["/".join(_key_str(k) for k in kp)] = spec

        def _pin_rest(rest):
            if rest_specs is None:
                return rest
            return {p: g if rest_specs.get(p) is None
                    else jax.lax.with_sharding_constraint(
                        g, NamedSharding(mesh, rest_specs[p]))
                    for p, g in rest.items()}

        def compute_grads_staged(params, batch):
            """(loss, staged owner-layout matrix grads, rest grads) with the
            stage_in all-to-alls issued inside the scan, per microbatch —
            under the next microbatch's fwd/bwd rather than after it."""
            def micro(carry, mb):
                loss_acc, staged_acc, rest_acc = carry
                loss, grads = jax.value_and_grad(loss_fn)(params, mb)
                gm, gr, _ = _matrix_and_rest(opt.plan, grads)
                st = pipe.stage_in_all(gm, dtype=accum_dtype)
                staged_acc = {k: pipe.layout.constrain(staged_acc[k] + st[k])
                              for k in staged_acc}
                rest_acc = _pin_rest(
                    {p: rest_acc[p] + gr[p].astype(accum_dtype)
                     for p in rest_acc})
                return (loss_acc + loss, staged_acc, rest_acc), None

            micro_batches = jax.tree.map(split, batch)
            zero_staged = pipe.zeros_staged(accum_dtype)
            _, rest_params, _ = _matrix_and_rest(opt.plan, params)
            zero_rest = _pin_rest({p: jnp.zeros(v.shape, accum_dtype)
                                   for p, v in rest_params.items()})
            (loss, staged, rest), _ = jax.lax.scan(
                micro, (jnp.zeros((), jnp.float32), zero_staged, zero_rest),
                micro_batches)
            inv = 1.0 / accum_steps
            return (loss * inv, {k: v * inv for k, v in staged.items()},
                    {p: g * inv for p, g in rest.items()})

    def step(state: TrainState, batch) -> TrainState:
        if prestage:
            loss, staged, rest = compute_grads_staged(state.params, batch)
            updates, opt_state = opt.update_staged(staged, rest,
                                                   state.opt_state,
                                                   state.params)
        else:
            loss, grads = compute_grads(state.params, batch)
            updates, opt_state = opt.update(grads, state.opt_state,
                                            state.params)
        params = jax.tree.map(jnp.add, state.params, updates)
        ema = jnp.where(state.step == 0, loss,
                        0.98 * state.loss_ema + 0.02 * loss)
        return TrainState(state.step + 1, params, opt_state, ema)

    # State enters pre-sharded (init_state) and batches pre-placed (pipeline);
    # jit infers in/out shardings from them, donation recycles the old state.
    return jax.jit(step, donate_argnums=(0,) if donate else ())


def init_state(cfg, opt: Muon, key, mesh=None, *, zero3: bool = False):
    """Initialize params (sharded via the partitioning rules) + opt state."""
    m = model_fns(cfg)

    def build():
        params = m.init(cfg, key)
        opt_state = opt.init(params)
        return TrainState(jnp.zeros((), jnp.int32), params, opt_state,
                          jnp.zeros((), jnp.float32))

    if mesh is None:
        return jax.jit(build)()

    shapes = jax.eval_shape(build)
    pspecs = shard_rules.param_specs(cfg, shapes.params, mesh, zero3=zero3)
    out_shardings = TrainState(
        step=NamedSharding(mesh, P()),
        params=jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs),
        opt_state=_opt_state_shardings(opt, shapes.opt_state, pspecs, mesh),
        loss_ema=NamedSharding(mesh, P()),
    )
    return jax.jit(build, out_shardings=out_shardings)()


def _opt_state_shardings(opt: Muon, opt_shapes, pspecs, mesh):
    """Momentum: owner layout (fully sharded stacks) for mode='owner';
    per-variant state (NorMuon neuron moments, MuonBP polar caches) shards
    the same way — owner-major axis 0, trailing dims replicated; AdamW
    moments follow their parameter's sharding."""
    from repro.core.muon import owner_sharding

    flat_pspecs = {}
    from repro.core.dedication import _key_str
    for kp, spec in jax.tree_util.tree_leaves_with_path(
            pspecs, is_leaf=lambda x: isinstance(x, P)):
        path = "/".join(_key_str(k) for k in kp)
        flat_pspecs[path] = spec

    own = owner_sharding(opt.plan, mesh) or NamedSharding(mesh, P())

    def mom_shard(path_prefix, tree):
        def one(kp, leaf):
            path = "/".join(_key_str(k) for k in kp)
            spec = flat_pspecs.get(path)
            return NamedSharding(mesh, spec if spec is not None else P())
        flat = jax.tree_util.tree_leaves_with_path(tree)
        treedef = jax.tree_util.tree_structure(tree)
        return jax.tree_util.tree_unflatten(
            treedef, [one(kp, l) for kp, l in flat])

    momentum = opt_shapes.momentum
    if opt.config.mode == "owner":
        mom_sh = jax.tree.map(lambda _: own, momentum)
    else:
        mom_sh = mom_shard("", momentum)
    from repro.core.muon import AdamWState, MuonState
    adam_sh = AdamWState(mu=mom_shard("", opt_shapes.adamw.mu),
                         nu=mom_shard("", opt_shapes.adamw.nu))
    ef = opt_shapes.error_feedback
    ef_sh = None if ef is None else mom_shard("", ef)
    vs = opt_shapes.variant_state
    vs_sh = None if vs is None else jax.tree.map(
        lambda leaf: owner_sharding(opt.plan, mesh, ndim=leaf.ndim)
        or NamedSharding(mesh, P()), vs)
    return MuonState(step=NamedSharding(mesh, P()), momentum=mom_sh,
                     adamw=adam_sh, error_feedback=ef_sh,
                     variant_state=vs_sh)
