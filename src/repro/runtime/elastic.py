"""Elastic scaling + straggler mitigation hooks (fault-tolerance runtime).

At thousands of nodes the failure model is: a host drops, the job restarts
on a different device set, training resumes from the last committed
checkpoint (checkpoint/manager.py) with the data pipeline replayed from the
stored step (data/pipeline.py determinism contract).  This module owns the
two decisions that change on such an event:

* ``remesh``             — rebuild the mesh for the surviving device count and
                           recompute every plan keyed on it (dedication plan,
                           shardings).  The dedication plan is a pure function
                           of (param shapes, mesh), so elastic re-planning is
                           a re-invocation, not a migration.
* ``StragglerMonitor``   — tracks per-step wall times; when drift beyond a
                           threshold persists, it re-solves the owner
                           assignment with per-owner ``speed`` factors
                           (core/load_balance.py) so a degraded host receives
                           proportionally fewer Muon updates — the paper's
                           measured-cost model applied online.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, List, Optional, Sequence

import numpy as np


def viable_mesh_shape(n_devices: int, prefer_model: int = 16):
    """Largest (data, model) grid for a (possibly degraded) device count."""
    model = min(prefer_model, n_devices)
    while n_devices % model:
        model -= 1
    return (n_devices // model, model)


def remesh(devices: Optional[Sequence] = None, prefer_model: int = 16):
    """Build a mesh over the currently-live devices."""
    import jax
    devices = list(devices if devices is not None else jax.devices())
    shape = viable_mesh_shape(len(devices), prefer_model)
    arr = np.asarray(devices[:shape[0] * shape[1]]).reshape(shape)
    from jax.sharding import Mesh
    return Mesh(arr, ("data", "model"))


@dataclass
class StragglerMonitor:
    """Detect persistent per-owner slowdowns and trigger rebalancing."""
    num_owners: int
    window: int = 20
    threshold: float = 1.3          # relative slowdown triggering rebalance
    _times: List[np.ndarray] = field(default_factory=list)

    def record(self, per_owner_seconds: np.ndarray) -> None:
        self._times.append(np.asarray(per_owner_seconds, dtype=float))
        if len(self._times) > self.window:
            self._times.pop(0)

    def speed_estimate(self) -> np.ndarray:
        """speed[r] ∈ (0, 1]: measured relative throughput per owner."""
        if not self._times:
            return np.ones(self.num_owners)
        med = np.median(np.stack(self._times), axis=0)
        fastest = med.min()
        return np.clip(fastest / np.maximum(med, 1e-12), 1e-3, 1.0)

    def should_rebalance(self) -> bool:
        if len(self._times) < self.window:
            return False
        speed = self.speed_estimate()
        return bool(speed.min() < 1.0 / self.threshold)

    def rebalance(self, shape_counts, cost_model, strategy: str = "greedy"):
        """Re-solve the assignment with measured speeds (one-line hook)."""
        from repro.core import load_balance
        return load_balance.assign(
            shape_counts, self.num_owners, strategy=strategy,
            cost_model=cost_model, speed=self.speed_estimate())


class StepTimer:
    """Wall-clock per step; feeds the monitor on real deployments where
    per-owner optimizer timings are exported by the profiler."""

    def __init__(self):
        self.t0 = None
        self.history: List[float] = []

    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        self.history.append(time.perf_counter() - self.t0)
