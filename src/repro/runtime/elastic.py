"""Elastic scaling + straggler mitigation hooks (fault-tolerance runtime).

At thousands of nodes the failure model is: a host drops, the job restarts
on a different device set, training resumes from the last committed
checkpoint (checkpoint/manager.py) with the data pipeline replayed from the
stored step (data/pipeline.py determinism contract).  This module owns the
two decisions that change on such an event:

* ``remesh``             — rebuild the mesh for the surviving device count and
                           recompute every plan keyed on it (dedication plan,
                           shardings).  The dedication plan is a pure function
                           of (param shapes, mesh), so elastic re-planning is
                           a re-invocation, not a migration.
* ``StragglerMonitor``   — tracks per-step wall times; when drift beyond a
                           threshold persists, it re-solves the owner
                           assignment with per-owner ``speed`` factors
                           (core/load_balance.py) so a degraded host receives
                           proportionally fewer Muon updates — the paper's
                           measured-cost model applied online.
"""

from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import Deque, Optional, Sequence

import numpy as np


def viable_mesh_shape(n_devices: int, prefer_model: int = 16):
    """Largest (data, model) grid for a (possibly degraded) device count.

    Raises ``ValueError`` when no devices survive — the caller (supervisor
    loop) must abort the job rather than divide by zero planning a mesh for
    an empty cluster.
    """
    if n_devices < 1:
        raise ValueError(
            f"cannot build a mesh over {n_devices} devices; the job has no "
            "survivors to remesh onto")
    model = min(prefer_model, n_devices)
    while n_devices % model:
        model -= 1
    return (n_devices // model, model)


def remesh(devices: Optional[Sequence] = None, prefer_model: int = 16):
    """Build a mesh over the currently-live devices."""
    import jax
    devices = list(devices if devices is not None else jax.devices())
    shape = viable_mesh_shape(len(devices), prefer_model)
    arr = np.asarray(devices[:shape[0] * shape[1]]).reshape(shape)
    from jax.sharding import Mesh
    return Mesh(arr, ("data", "model"))


@dataclass
class StragglerMonitor:
    """Detect persistent per-owner slowdowns and trigger rebalancing.

    Memory is bounded by construction: ``_times`` is a deque capped at
    ``window`` samples, so a months-long run holds ``window × num_owners``
    floats however many steps it takes.
    """
    num_owners: int
    window: int = 20
    threshold: float = 1.3          # relative slowdown triggering rebalance
    _times: Deque[np.ndarray] = field(default_factory=deque)

    def __post_init__(self):
        self._times = deque(self._times, maxlen=self.window)

    def record(self, per_owner_seconds: np.ndarray) -> None:
        self._times.append(np.asarray(per_owner_seconds, dtype=float))

    def reset(self) -> None:
        """Drop history — after a rebalance/remesh the samples describe the
        previous assignment and must not vote on the next one."""
        self._times.clear()

    def speed_estimate(self) -> np.ndarray:
        """speed[r] ∈ (0, 1]: measured relative throughput per owner."""
        if not self._times:
            return np.ones(self.num_owners)
        med = np.median(np.stack(self._times), axis=0)
        fastest = med.min()
        return np.clip(fastest / np.maximum(med, 1e-12), 1e-3, 1.0)

    def should_rebalance(self) -> bool:
        if len(self._times) < self.window:
            return False
        speed = self.speed_estimate()
        return bool(speed.min() < 1.0 / self.threshold)

    def rebalance(self, shape_counts, cost_model, strategy: str = "greedy"):
        """Re-solve the assignment with measured speeds (one-line hook)."""
        from repro.core import load_balance
        return load_balance.assign(
            shape_counts, self.num_owners, strategy=strategy,
            cost_model=cost_model, speed=self.speed_estimate())


class StepTimer:
    """Wall-clock per step; feeds the monitor on real deployments where
    per-owner optimizer timings are exported by the profiler.

    ``history`` is bounded (default 1024 samples) so long-run supervisors
    don't grow a float per step forever; ``recent(n)`` and ``last`` cover
    the logging uses.
    """

    def __init__(self, max_history: int = 1024):
        self.t0 = None
        self.history: Deque[float] = deque(maxlen=max_history)

    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        self.history.append(time.perf_counter() - self.t0)

    @property
    def last(self) -> float:
        return self.history[-1]

    def recent(self, n: int) -> list:
        """The most recent ``n`` samples (deques don't slice)."""
        n = min(n, len(self.history))
        return [self.history[i] for i in range(len(self.history) - n,
                                               len(self.history))]
