"""Survivable training loop: streaming data + checkpoints + elasticity +
online straggler rebalancing under one supervisor (docs/DESIGN.md §11).

This is the composition the ROADMAP's "long-run resilience" item asks for.
The pieces existed in isolation — ``data/pipeline.py`` (deterministic
prefetching stream), ``checkpoint/manager.py`` (atomic, rotating, async
shard-aware checkpoints), ``runtime/elastic.py`` (remesh + StragglerMonitor),
``core/load_balance.py`` (per-owner ``speed`` factors), ``reshard_owner_state``
(owner-count migration) — and ``ResilientLoop`` wires them into one loop with
three recovery behaviours:

* **online rebalance** — per-owner step timings feed the ``StragglerMonitor``;
  when a persistent slowdown crosses the threshold the dedication plan is
  re-solved with the *measured* speeds (the paper's measured-cost model
  applied online) and the owner-sharded optimizer state migrates through
  ``reshard_owner_state`` — no restart, no trajectory change.  Hysteresis:
  the speeds baked into the live plan are remembered, and a re-solve fires
  only when the estimate drifts beyond the threshold *relative to them*
  (otherwise a permanently-slow-but-already-rebalanced host would re-fire
  every ``window`` steps forever).
* **owner loss / re-add** — a ``kill`` fault (or, on a real mesh, a device
  loss) shrinks the owner set: the loop remeshes (``remesh``), re-plans at
  the surviving count, migrates momentum + per-variant state, and continues
  the same logical trajectory.  ``readd`` is the inverse.
* **preemption** — the whole job dies and resumes from the latest committed
  checkpoint, which carries the train tree (params + owner-sharded
  ``MuonState`` incl. ``variant_state``), the data-pipeline cursor
  (``Pipeline.state()``) and the owner count at save time — so the resumed
  run replays batch k, k+1, ... exactly and, if the owner count changed in
  between, reshards the restored state onto the live plan.

Invariant (tests/test_resilience.py): the *logical* optimizer trajectory —
params, loss curve, and the unpacked per-matrix rows of momentum and variant
state — is bit-identical to an unfaulted run at equal step counts, for every
registry variant.  This holds because (a) the per-matrix NS math is
independent of which owner slot computes it, (b) ``reshard_owner_state`` is
an exact permutation of logical rows, and (c) the data stream is a pure
function of (seed, step).

In-flight staged accumulators (the accumulation-overlapped bucketed
pipeline) never cross a recovery boundary: faults are handled between steps,
where staged gradient state exists only inside the jit'd step program.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np

from repro.runtime.elastic import StepTimer, StragglerMonitor, remesh
from repro.runtime.faults import (FaultInjector, FaultPlan, OwnerLost,
                                  Preemption)


@dataclass
class ResilientConfig:
    """Supervisor policy (everything but the optimizer math)."""
    steps: int = 50
    ckpt_every: int = 0             # 0 = no checkpointing
    strategy: str = "greedy"        # dedication strategy for every (re)plan
    accum_steps: int = 1
    donate: bool = False            # buffer donation in the jit'd step
    # straggler policy
    rebalance: bool = True
    window: int = 8                 # monitor window (steps)
    threshold: float = 1.3          # slowdown ratio that triggers a re-solve
    cooldown: int = 10              # min steps between plan changes
    max_history: int = 1024         # StepTimer bound
    seed: int = 0                   # model init PRNG


@dataclass
class LoopReport:
    """Telemetry of one supervised run (consumed by tests + soak bench)."""
    steps: int = 0                       # logical steps completed
    executed_steps: int = 0              # including replays after preemption
    losses: Dict[int, float] = field(default_factory=dict)   # step -> ema
    step_times: List[float] = field(default_factory=list)
    rebalances: List[dict] = field(default_factory=list)
    recoveries: List[dict] = field(default_factory=list)
    checkpoints: List[int] = field(default_factory=list)
    final_owner_count: int = 0

    def loss_curve(self) -> List[float]:
        """EMA loss by logical step (replayed steps overwrite identically)."""
        return [self.losses[s] for s in sorted(self.losses)]


class ResilientLoop:
    """One supervised production training run (see module docstring).

    Always plans with the default *contiguous* physical layout: plans of
    equal owner count then share pack indices whatever the logical
    assignment, which is what lets a rebalance reuse the compiled step
    (no recompile) and keep bit-identity by construction.
    """

    def __init__(self, model_cfg, data_cfg, *, muon=None, run=None,
                 num_owners: int = 1, mesh=None, ckpt_dir: Optional[str] = None,
                 ckpt_keep: int = 3, faults: Optional[FaultPlan] = None,
                 resume: bool = False, log=None):
        import jax

        from repro.checkpoint.manager import CheckpointManager
        from repro.core.muon import MuonConfig
        from repro.data.pipeline import Pipeline
        from repro.models import model_fns

        self.model_cfg = model_cfg
        self.data_cfg = data_cfg
        self.muon_cfg = muon or MuonConfig()
        self.rcfg = run or ResilientConfig()
        self.mesh = mesh
        self.log = log or (lambda *a, **k: None)
        self.report = LoopReport()

        self.shapes = jax.eval_shape(
            lambda k: model_fns(model_cfg).init(model_cfg, k),
            jax.random.PRNGKey(self.rcfg.seed))
        self._step_cache: dict = {}      # plan signature -> compiled step
        self._install(self._plan_for(num_owners=num_owners))
        self._plan_speed = np.ones(self.num_owners)
        self._last_plan_change = -self.rcfg.cooldown

        self.mgr = (CheckpointManager(ckpt_dir, keep=ckpt_keep)
                    if ckpt_dir else None)
        self.injector = FaultInjector(faults) if faults is not None else None
        self.timer = StepTimer(max_history=self.rcfg.max_history)
        self.monitor = StragglerMonitor(
            num_owners=self.num_owners, window=self.rcfg.window,
            threshold=self.rcfg.threshold)

        from repro.train.step import init_state
        self.state = init_state(model_cfg, self.opt,
                                jax.random.PRNGKey(self.rcfg.seed), mesh=mesh)
        self.pipe = Pipeline(data_cfg, mesh=mesh, start_step=0,
                             sharding=None)
        if resume and self.mgr is not None and self.mgr.latest_step():
            self._restore_from_checkpoint()

    # ------------------------------------------------------------ planning

    def _plan_for(self, num_owners: Optional[int] = None, speed=None):
        from repro.core import api
        if self.mesh is not None:
            return api.dedicate_params(self.shapes, mesh=self.mesh,
                                       strategy=self.rcfg.strategy,
                                       speed=speed)
        return api.dedicate_params(self.shapes, num_owners=num_owners,
                                   strategy=self.rcfg.strategy, speed=speed)

    @staticmethod
    def _plan_signature(plan):
        """Physical-layout key: plans with equal signatures produce the same
        compiled step program (the logical assignment is scheduling
        metadata, not computation)."""
        return tuple(sorted(
            (path, g.key, g.count, g.capacity, plan.num_owners)
            for path, g in plan.groups.items()))

    def _install(self, plan) -> None:
        from repro.core import api
        from repro.train.step import make_train_step
        self.plan = plan
        self.num_owners = plan.num_owners
        self.opt = api.Muon(plan, self.mesh, config=self.muon_cfg)
        sig = self._plan_signature(plan)
        if sig not in self._step_cache:
            self._step_cache[sig] = make_train_step(
                self.model_cfg, self.opt, self.mesh,
                accum_steps=self.rcfg.accum_steps, donate=self.rcfg.donate)
        self.step_fn = self._step_cache[sig]

    # --------------------------------------------------------- checkpoints

    def _checkpoint_tree(self):
        return {"train": self.state._asdict(),
                "data": self.pipe.state(),
                "meta": {"num_owners": np.asarray(self.num_owners,
                                                  np.int64)}}

    def _save_checkpoint(self, step: int, *, block: bool = False) -> None:
        if self.mgr is None:
            return
        self.mgr.save(step, self._checkpoint_tree(), block=block)
        self.report.checkpoints.append(step)

    def _restore_from_checkpoint(self) -> int:
        """Rebuild (state, data cursor) from the latest committed checkpoint;
        reshards the owner-sharded state if the live owner count differs from
        the one at save time.  Returns the resumed step."""
        from repro.core.api import reshard_owner_state
        from repro.train.train_state import TrainState
        like = None
        if self.mesh is not None:
            try:
                like = self._checkpoint_tree()
            except Exception:           # structure drifted; restore replicated
                like = None
        tree = self.mgr.restore(like=like)
        state = TrainState(**tree["train"])
        saved_owners = int(np.asarray(tree["meta"]["num_owners"]))
        if saved_owners != self.num_owners:
            saved_plan = self._plan_for(num_owners=saved_owners)
            opt_state = reshard_owner_state(state.opt_state, saved_plan,
                                            self.plan, self.mesh)
            state = TrainState(state.step, state.params, opt_state,
                               state.loss_ema)
        self.state = state
        self.pipe.restore(tree["data"])
        return int(np.asarray(state.step))

    # ----------------------------------------------------------- recovery

    def _migrate(self, new_plan) -> None:
        """Move the owner-sharded optimizer state onto ``new_plan`` and make
        it the live plan (exact permutation of logical rows)."""
        from repro.core.api import reshard_owner_state
        from repro.train.train_state import TrainState
        opt_state = reshard_owner_state(self.state.opt_state, self.plan,
                                        new_plan, self.mesh)
        self._install(new_plan)
        self.state = TrainState(self.state.step, self.state.params,
                                opt_state, self.state.loss_ema)

    def _rebalance(self, speed: np.ndarray, step: int) -> None:
        """Re-solve the dedication with measured speeds; migrate in place."""
        t0 = time.perf_counter()
        old_plan = self.plan
        new_plan = self._plan_for(num_owners=self.num_owners, speed=speed)
        self._migrate(new_plan)
        latency = time.perf_counter() - t0
        cm = new_plan.cost_model or old_plan.cost_model
        before = after = None
        if cm is not None:
            before = old_plan.assignment.makespan(cm, speed=speed)
            after = new_plan.assignment.makespan(cm, speed=speed)
        self._plan_speed = np.asarray(speed, float)
        self._last_plan_change = step
        self.monitor.reset()
        self.report.rebalances.append({
            "step": step, "latency_s": latency, "speed": speed.tolist(),
            "makespan_before_s": before, "makespan_after_s": after})
        self.log(f"[rebalance] step {step}: speeds={np.round(speed, 3)} "
                 f"makespan {before} -> {after} ({latency*1e3:.0f} ms)")

    def _resize_owners(self, new_count: int, *, kind: str, step: int,
                       owner: int = -1) -> None:
        """Shared kill/readd path: remesh (if meshed), re-plan, migrate."""
        if new_count < 1:
            raise RuntimeError(
                f"owner loss at step {step} leaves no survivors")
        t0 = time.perf_counter()
        if self.mesh is not None:
            import jax
            live = list(self.mesh.devices.flat)
            if kind == "kill" and 0 <= owner < len(live):
                live = live[:owner] + live[owner + 1:]
            elif kind == "readd":
                live = list(jax.devices())
            self.mesh = remesh(live)
            new_plan = self._plan_for()
        else:
            new_plan = self._plan_for(num_owners=new_count)
        old_count = self.num_owners
        self._migrate(new_plan)
        latency = time.perf_counter() - t0
        if kind == "kill" and self.injector is not None:
            self.injector.on_owner_renumber(owner)
        self.monitor = StragglerMonitor(
            num_owners=self.num_owners, window=self.rcfg.window,
            threshold=self.rcfg.threshold)
        self._plan_speed = np.ones(self.num_owners)
        self._last_plan_change = step
        self.report.recoveries.append({
            "kind": kind, "step": step, "owner": owner,
            "owners": (old_count, self.num_owners), "latency_s": latency})
        self.log(f"[{kind}] step {step}: owners {old_count} -> "
                 f"{self.num_owners} ({latency*1e3:.0f} ms)")

    def _recover_preemption(self, step: int) -> int:
        """The job died; resume from the latest committed checkpoint (or from
        scratch when none committed yet).  Returns the step to resume at."""
        import jax
        t0 = time.perf_counter()
        resumed = 0
        if self.mgr is not None and self.mgr.latest_step() is not None:
            resumed = self._restore_from_checkpoint()
        else:
            from repro.train.step import init_state
            self.state = init_state(self.model_cfg, self.opt,
                                    jax.random.PRNGKey(self.rcfg.seed),
                                    mesh=self.mesh)
            self.pipe.seek(0)
        latency = time.perf_counter() - t0
        self.report.recoveries.append({
            "kind": "preempt", "step": step, "resumed_step": resumed,
            "owners": (self.num_owners, self.num_owners),
            "latency_s": latency})
        self.log(f"[preempt] step {step}: resumed at {resumed} "
                 f"({latency*1e3:.0f} ms)")
        return resumed

    # ---------------------------------------------------------- main loop

    def _owner_times(self, wall_s: float) -> np.ndarray:
        """Per-owner step times as a profiler would export them.  SPMD makes
        every owner's wall clock the step time; injected slow factors model
        the degraded hosts the monitor is there to catch."""
        per_owner = np.full(self.num_owners, wall_s)
        if self.injector is not None:
            per_owner = self.injector.perturb(per_owner)
        return per_owner

    def _maybe_rebalance(self, step: int) -> None:
        if not self.rcfg.rebalance:
            return
        if step - self._last_plan_change < self.rcfg.cooldown:
            return
        if not self.monitor.should_rebalance():
            return
        est = self.monitor.speed_estimate()
        ref = self._plan_speed
        drift = float(np.max(np.maximum(est, ref)
                             / np.maximum(np.minimum(est, ref), 1e-9)))
        if drift <= self.rcfg.threshold:
            return                       # already planned for these speeds
        self._rebalance(est, step)

    def _raise_faults(self, step: int) -> None:
        """Poll the fault script for ``step``.  slow/unslow apply silently
        inside the injector; a control event surfaces as the exception a
        real runtime failure would (device loss, SIGTERM) and the supervisor
        recovers and re-polls, so stacked same-step faults strike one at a
        time against the already-recovered topology."""
        if self.injector is None:
            return
        for ev in self.injector.events_at(step):
            if ev.kind == "kill":
                raise OwnerLost(ev.owner)
            if ev.kind == "preempt":
                raise Preemption()
            if ev.kind == "readd":
                self._resize_owners(self.num_owners + 1, kind="readd",
                                    step=step)

    def run(self) -> LoopReport:
        import jax
        step = int(np.asarray(self.state.step))
        try:
            while step < self.rcfg.steps:
                try:
                    self._raise_faults(step)
                except OwnerLost as e:
                    self._resize_owners(self.num_owners - 1, kind="kill",
                                        step=step, owner=e.owner)
                    continue                 # re-poll the same step
                except Preemption:
                    step = self._recover_preemption(step)
                    continue

                batch = next(self.pipe)
                with self.timer:
                    self.state = self.step_fn(self.state, batch)
                    jax.block_until_ready(self.state.loss_ema)
                self.report.executed_steps += 1
                self.report.losses[step] = float(self.state.loss_ema)
                self.report.step_times.append(self.timer.last)
                self.monitor.record(self._owner_times(self.timer.last))
                step += 1
                if step % 10 == 0:
                    self.log(f"step {step:5d} loss_ema "
                             f"{float(self.state.loss_ema):.4f} "
                             f"{np.mean(self.timer.recent(10))*1e3:.0f} "
                             f"ms/step")

                self._maybe_rebalance(step)
                if self.rcfg.ckpt_every and step % self.rcfg.ckpt_every == 0:
                    self._save_checkpoint(step)
        finally:
            self.pipe.close()
            if self.mgr is not None:
                self.mgr.wait()
        self.report.steps = step
        self.report.final_owner_count = self.num_owners
        return self.report
