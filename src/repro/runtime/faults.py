"""Fault-injection harness for the resilient training loop (scripted adversity).

A ``FaultPlan`` is a step-indexed script of the failure modes the paper's
production story must survive, expressed either programmatically or through a
tiny text DSL (one event per ``;``/``,``-separated clause):

    slow@8:r3x4.0      owner slot 3 runs 4.0x slower starting at step 8
    unslow@24:r3       owner slot 3 recovers its nominal speed at step 24
    kill@30:r1         the host behind owner slot 1 is lost at step 30
    readd@40           a replacement host joins at step 40 (owner count +1)
    preempt@52         the whole job is preempted at step 52 and restarts
                       from its latest committed checkpoint

Owner ids refer to the slot numbering of the plan live when the event fires
(a kill renumbers the survivors, exactly as an elastic re-plan does).

``FaultInjector`` is the runtime half: the supervisor polls ``events_at`` at
the top of every step; ``kill``/``preempt`` surface as ``OwnerLost``/
``Preemption`` exceptions (modeling the abrupt control-flow of a real device
loss), while ``slow``/``unslow`` mutate the injector's per-owner speed
multipliers, which ``perturb`` applies to the measured per-owner step times
fed to the StragglerMonitor.  Slow factors persist across a preemption — a
degraded host is still degraded after the job restarts.

Consumed by ``runtime/resilient.py``, ``tests/test_resilience.py`` and
``benchmarks/soak_bench.py``.
"""

from __future__ import annotations

import re
from dataclasses import dataclass
from typing import Dict, Iterable, List, Tuple

import numpy as np

KINDS = ("slow", "unslow", "kill", "readd", "preempt")
_EVENT_RE = re.compile(
    r"^(?P<kind>[a-z]+)@(?P<step>\d+)"
    r"(?::r(?P<owner>\d+))?(?:x(?P<factor>\d+(?:\.\d+)?))?$")


class OwnerLost(RuntimeError):
    """The host behind one owner slot dropped out of the job."""

    def __init__(self, owner: int):
        super().__init__(f"owner slot {owner} lost")
        self.owner = owner


class Preemption(RuntimeError):
    """The whole job was preempted; resume from the latest checkpoint."""


@dataclass(frozen=True)
class FaultEvent:
    step: int
    kind: str               # one of KINDS
    owner: int = -1         # slot id for slow/unslow/kill
    factor: float = 1.0     # slowdown multiplier for 'slow'

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"known: {KINDS}")
        if self.step < 0:
            raise ValueError(f"fault step must be >= 0 (got {self.step})")
        if self.kind in ("slow", "unslow", "kill") and self.owner < 0:
            raise ValueError(f"{self.kind!r} needs an owner slot (':rN')")
        if self.kind == "slow" and self.factor < 1.0:
            raise ValueError(
                f"slow factor must be >= 1.0 (got {self.factor}); use "
                "'unslow' to restore nominal speed")

    def spec(self) -> str:
        """The DSL clause that parses back to this event."""
        s = f"{self.kind}@{self.step}"
        if self.kind in ("slow", "unslow", "kill"):
            s += f":r{self.owner}"
        if self.kind == "slow":
            s += f"x{self.factor:g}"
        return s


class FaultPlan:
    """An ordered script of fault events, indexable by step."""

    def __init__(self, events: Iterable[FaultEvent] = ()):
        self.events: Tuple[FaultEvent, ...] = tuple(
            sorted(events, key=lambda e: e.step))

    @classmethod
    def parse(cls, spec: str) -> "FaultPlan":
        """Parse the text DSL (see module docstring)."""
        events = []
        for clause in re.split(r"[;,]", spec):
            clause = clause.strip()
            if not clause:
                continue
            m = _EVENT_RE.match(clause)
            if m is None:
                raise ValueError(
                    f"bad fault clause {clause!r}; expected "
                    "'kind@step[:rOWNER][xFACTOR]' with kind in "
                    f"{KINDS}")
            events.append(FaultEvent(
                step=int(m.group("step")), kind=m.group("kind"),
                owner=int(m.group("owner") or -1),
                factor=float(m.group("factor") or 1.0)))
        return cls(events)

    def spec(self) -> str:
        return "; ".join(e.spec() for e in self.events)

    def at(self, step: int) -> List[FaultEvent]:
        return [e for e in self.events if e.step == step]

    @property
    def max_step(self) -> int:
        return max((e.step for e in self.events), default=-1)

    def __len__(self) -> int:
        return len(self.events)

    def __repr__(self) -> str:
        return f"FaultPlan({self.spec()!r})"


class FaultInjector:
    """Runtime driver of a FaultPlan against a supervisor loop.

    Each scripted event fires exactly once: a preemption rewinds the loop's
    step counter to the checkpointed step, and replayed steps must not
    re-raise the faults that already struck (the real-world analogue: the
    failure happened to the previous incarnation of the job).
    """

    def __init__(self, plan: FaultPlan):
        self.plan = plan
        self._fired: set = set()
        self._slow: Dict[int, float] = {}       # owner slot -> multiplier

    def events_at(self, step: int) -> List[FaultEvent]:
        """Unfired events scheduled for ``step``; marks them fired and
        applies slow/unslow to the injector's multiplier table.  The caller
        handles kill/readd/preempt (they change loop topology).  At most ONE
        control event is returned per poll — the supervisor recovers from it
        and re-polls the same step, so stacked same-step faults strike one
        at a time (each against the already-recovered topology)."""
        out = []
        for ev in self.plan.at(step):
            if ev in self._fired:
                continue
            self._fired.add(ev)
            if ev.kind == "slow":
                self._slow[ev.owner] = ev.factor
            elif ev.kind == "unslow":
                self._slow.pop(ev.owner, None)
            out.append(ev)
            if ev.kind in ("kill", "readd", "preempt"):
                break
        return out

    def on_owner_renumber(self, killed: int) -> None:
        """A kill compacts slot ids: slots above the lost one shift down by
        one, and their slow factors follow the hosts they describe."""
        self._slow = {(r - 1 if r > killed else r): f
                      for r, f in self._slow.items() if r != killed}

    def multipliers(self, num_owners: int) -> np.ndarray:
        """Per-owner wall-time multipliers under the active slow faults."""
        mult = np.ones(num_owners)
        for r, f in self._slow.items():
            if 0 <= r < num_owners:
                mult[r] = f
        return mult

    def perturb(self, per_owner_seconds: np.ndarray) -> np.ndarray:
        per_owner_seconds = np.asarray(per_owner_seconds, dtype=float)
        return per_owner_seconds * self.multipliers(len(per_owner_seconds))
